//! Hardware-counter analog: the counter set `perf_event` exposes,
//! synthesized from the cost model the way the real ones come from the
//! silicon.
//!
//! The paper (§3.1) reads cycles, cache misses, branch misses and page
//! faults, and uses *cycles* as the sole off-load metric, leaving "the
//! choice about which figure of merit optimize for, to the system
//! engineer".  We synthesize all four so extensions (e.g. the
//! cache-conscious restructuring the paper cites as future work) have the
//! data they would need.

use crate::platform::TargetId;
use crate::workloads::WorkloadKind;

/// The counters VPE's sampler can multiplex.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CounterKind {
    /// CPU cycles (always on — the off-load metric).
    Cycles,
    /// Retired instructions.
    Instructions,
    /// Last-level cache misses.
    CacheMisses,
    /// Mispredicted branches.
    BranchMisses,
    /// Page faults.
    PageFaults,
}

impl CounterKind {
    /// Every counter the sampler can multiplex.
    pub const ALL: [CounterKind; 5] = [
        CounterKind::Cycles,
        CounterKind::Instructions,
        CounterKind::CacheMisses,
        CounterKind::BranchMisses,
        CounterKind::PageFaults,
    ];
}

/// One sampled execution of one function.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CounterSample {
    /// CPU cycles spent in the call.
    pub cycles: u64,
    /// Retired instructions.
    pub instructions: u64,
    /// Last-level cache misses.
    pub cache_misses: u64,
    /// Mispredicted branches.
    pub branch_misses: u64,
    /// Page faults.
    pub page_faults: u64,
}

impl CounterSample {
    /// The value of one counter.
    pub fn get(&self, kind: CounterKind) -> u64 {
        match kind {
            CounterKind::Cycles => self.cycles,
            CounterKind::Instructions => self.instructions,
            CounterKind::CacheMisses => self.cache_misses,
            CounterKind::BranchMisses => self.branch_misses,
            CounterKind::PageFaults => self.page_faults,
        }
    }

    /// Synthesize the counter set for one call from the simulated
    /// execution: `exec_ns` of compute on `target` over `items`
    /// inner-loop items of `kind`.
    ///
    /// Per-workload event rates are rough micro-architectural estimates —
    /// VPE only *decides* on cycles, but the rates give the other
    /// counters realistic relative magnitudes (e.g. the naive matmul's
    /// cache thrashing).
    pub fn synthesize(
        kind: WorkloadKind,
        items: f64,
        exec_ns: f64,
        target: TargetId,
        freq_hz: u64,
    ) -> Self {
        let cycles = (exec_ns * freq_hz as f64 / 1e9) as u64;
        // Instructions per item: accelerator builds (anything off the
        // host) pack more work per instruction (VLIW bundles, vector
        // lanes).
        let ipi = if target.is_host() { 6.0 } else { 1.5 };
        // Cache-miss rate per item (the naive host matmul thrashes;
        // accelerators stream through scratchpads via DMA).
        let miss_rate = match (kind, target.is_host()) {
            (WorkloadKind::Matmul, true) => 0.5,
            (WorkloadKind::Matmul, false) => 0.02,
            (_, true) => 0.05,
            (_, false) => 0.01,
        };
        let branch_rate = match kind {
            WorkloadKind::Pattern => 0.2, // data-dependent compares
            _ => 0.02,
        };
        CounterSample {
            cycles,
            instructions: (items * ipi) as u64,
            cache_misses: (items * miss_rate) as u64,
            branch_misses: (items * branch_rate) as u64,
            // Touched pages: items-scaled, tiny.
            page_faults: (items / 1e6) as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::dm3730;

    #[test]
    fn cycles_follow_exec_time_and_frequency() {
        let s = CounterSample::synthesize(
            WorkloadKind::Matmul,
            1e6,
            1_000_000.0, // 1 ms
            TargetId::HOST,
            1_000_000_000,
        );
        assert_eq!(s.cycles, 1_000_000);
        let d = CounterSample::synthesize(
            WorkloadKind::Matmul,
            1e6,
            1_000_000.0,
            dm3730::DSP,
            800_000_000,
        );
        assert_eq!(d.cycles, 800_000);
    }

    #[test]
    fn naive_matmul_thrashes_caches_dsp_does_not() {
        let arm = CounterSample::synthesize(
            WorkloadKind::Matmul, 1e6, 1e6, TargetId::HOST, 1_000_000_000,
        );
        let dsp = CounterSample::synthesize(
            WorkloadKind::Matmul, 1e6, 1e6, dm3730::DSP, 800_000_000,
        );
        assert!(arm.cache_misses > 10 * dsp.cache_misses);
    }

    #[test]
    fn get_covers_all_kinds() {
        let s = CounterSample {
            cycles: 1,
            instructions: 2,
            cache_misses: 3,
            branch_misses: 4,
            page_faults: 5,
        };
        let got: Vec<u64> = CounterKind::ALL.iter().map(|&k| s.get(k)).collect();
        assert_eq!(got, vec![1, 2, 3, 4, 5]);
    }
}
