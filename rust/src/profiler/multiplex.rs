//! Counter multiplexing — the part of `perf_event` the paper leans on
//! when it says "very interesting measures can be acquired, including
//! cache misses, branch misses, page faults" (§3.1).
//!
//! Real PMUs have a small number of hardware counter slots (the
//! Cortex-A8 has 4 + the cycle counter); when more events are requested
//! than slots exist, the kernel time-slices the counters across the
//! run and *scales* each reading by `time_enabled / time_running`.
//! This module reproduces that mechanism: a rotation schedule over the
//! requested events, per-event running-time accounting, and the scaled
//! estimate with its enabled/running ratio — so consumers can see (and
//! tests can assert) the estimation error multiplexing introduces.

use std::collections::HashMap;

use super::counters::{CounterKind, CounterSample};

/// Number of programmable PMU slots (Cortex-A8: 4 events + cycles,
/// which has its own dedicated counter).
pub const PMU_SLOTS: usize = 4;

/// A scaled counter estimate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScaledCount {
    /// Raw counted value while the event was scheduled.
    pub counted: u64,
    /// Extrapolated estimate over the whole run.
    pub estimate: u64,
    /// time_running / time_enabled (1.0 = never multiplexed out).
    pub running_ratio: f64,
}

/// Round-robin multiplexer over a requested event set.
#[derive(Debug, Clone)]
pub struct Multiplexer {
    events: Vec<CounterKind>,
    slots: usize,
    /// Rotation cursor: which window of `PMU_SLOTS` events is live.
    cursor: usize,
    /// Per-event (counted value, intervals running, intervals enabled).
    state: HashMap<CounterKind, (u64, u64, u64)>,
}

impl Multiplexer {
    /// Multiplex `events` across the PMU.  Cycles never multiplex (the
    /// dedicated counter), so they are excluded from the rotation.
    pub fn new(events: &[CounterKind]) -> Self {
        Self::with_slots(events, PMU_SLOTS)
    }

    /// Multiplexer with an explicit slot count (other PMUs; tests).
    pub fn with_slots(events: &[CounterKind], slots: usize) -> Self {
        let events: Vec<CounterKind> =
            events.iter().copied().filter(|e| *e != CounterKind::Cycles).collect();
        Multiplexer { events, slots: slots.max(1), cursor: 0, state: HashMap::new() }
    }

    /// Is the rotation actually needed?
    pub fn is_multiplexing(&self) -> bool {
        self.events.len() > self.slots
    }

    /// Events live in the current rotation window.
    pub fn live_events(&self) -> Vec<CounterKind> {
        if !self.is_multiplexing() {
            return self.events.clone();
        }
        (0..self.slots)
            .map(|i| self.events[(self.cursor + i) % self.events.len()])
            .collect()
    }

    /// Account one sampling interval: live events count their true
    /// deltas, parked events only accrue enabled-time.  Rotates after.
    pub fn observe(&mut self, truth: &CounterSample) {
        let live = self.live_events();
        for &e in &self.events {
            let entry = self.state.entry(e).or_insert((0, 0, 0));
            entry.2 += 1; // enabled
            if live.contains(&e) {
                entry.0 += truth.get(e);
                entry.1 += 1; // running
            }
        }
        if self.is_multiplexing() {
            self.cursor = (self.cursor + self.slots) % self.events.len();
        }
    }

    /// Scaled estimate for an event (perf's `count * enabled/running`).
    pub fn read(&self, event: CounterKind) -> Option<ScaledCount> {
        let (counted, running, enabled) = *self.state.get(&event)?;
        if running == 0 {
            return Some(ScaledCount { counted: 0, estimate: 0, running_ratio: 0.0 });
        }
        Some(ScaledCount {
            counted,
            estimate: (counted as f64 * enabled as f64 / running as f64) as u64,
            running_ratio: running as f64 / enabled as f64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn truth() -> CounterSample {
        CounterSample {
            cycles: 1000,
            instructions: 4000,
            cache_misses: 80,
            branch_misses: 40,
            page_faults: 2,
        }
    }

    #[test]
    fn no_multiplexing_when_events_fit() {
        let mut m = Multiplexer::new(&[CounterKind::Instructions, CounterKind::CacheMisses]);
        assert!(!m.is_multiplexing());
        for _ in 0..10 {
            m.observe(&truth());
        }
        let r = m.read(CounterKind::Instructions).unwrap();
        assert_eq!(r.counted, 40_000);
        assert_eq!(r.estimate, 40_000);
        assert_eq!(r.running_ratio, 1.0);
    }

    #[test]
    fn cycles_never_enter_the_rotation() {
        let m = Multiplexer::new(&CounterKind::ALL);
        assert!(!m.live_events().contains(&CounterKind::Cycles));
    }

    #[test]
    fn scaling_recovers_steady_rates_under_rotation() {
        // Squeeze 4 events into 2 slots: each runs ~half the time, and
        // the scaled estimate must still recover the true totals for a
        // steady-rate workload.
        let events = [
            CounterKind::Instructions,
            CounterKind::CacheMisses,
            CounterKind::BranchMisses,
            CounterKind::PageFaults,
        ];
        let mut m = Multiplexer::with_slots(&events, 2);
        assert!(m.is_multiplexing());
        let n = 100;
        for _ in 0..n {
            m.observe(&truth());
        }
        let t = truth();
        for e in events {
            let est = m.read(e).unwrap();
            assert!((est.running_ratio - 0.5).abs() < 0.01, "{e:?}: {}", est.running_ratio);
            let want = t.get(e) * n;
            let rel = (est.estimate as f64 - want as f64).abs() / want as f64;
            assert!(rel < 0.05, "{e:?}: estimate {} vs true {want}", est.estimate);
            assert!(est.counted < want, "{e:?} must have missed intervals");
        }
    }

    #[test]
    fn bursty_event_is_misestimated_under_rotation() {
        // Multiplexing's known failure mode: a bursty event landing in
        // the parked window is extrapolated wrongly — worth surfacing
        // so consumers treat scaled counts as estimates.
        let mut m = Multiplexer::with_slots(
            &[CounterKind::Instructions, CounterKind::CacheMisses,
              CounterKind::BranchMisses, CounterKind::PageFaults],
            2,
        );
        let quiet = CounterSample { instructions: 10, ..Default::default() };
        let burst = CounterSample { instructions: 10, cache_misses: 10_000, ..Default::default() };
        // Bursts land only on odd intervals; whether they are counted
        // depends on the rotation phase.
        for i in 0..50 {
            m.observe(if i % 2 == 1 { &burst } else { &quiet });
        }
        let est = m.read(CounterKind::CacheMisses).unwrap();
        let true_total = 25 * 10_000;
        assert_ne!(est.estimate, true_total, "estimate happened to be exact — rotation broken?");
    }

    #[test]
    fn unread_event_is_none() {
        let m = Multiplexer::new(&[CounterKind::Instructions]);
        assert!(m.read(CounterKind::CacheMisses).is_none());
    }
}
