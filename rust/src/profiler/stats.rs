//! Rolling statistics over profiler samples (Welford + EWMA).

/// Streaming mean / variance / min / max (Welford's algorithm — numerically
/// stable, O(1) per sample).
#[derive(Debug, Clone, Default)]
pub struct RollingStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl RollingStats {
    /// Empty statistics (mean is NaN until the first push).
    pub fn new() -> Self {
        RollingStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold one sample in (O(1), numerically stable).
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of samples pushed.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean (NaN with no samples).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Sample standard deviation (n-1 denominator).
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    /// Smallest sample seen (infinity with no samples).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest sample seen (-infinity with no samples).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Sum of all pushed samples.
    pub fn total(&self) -> f64 {
        self.mean() * self.n as f64
    }
}

/// Exponentially weighted moving average.
#[derive(Debug, Clone, Copy)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Default for Ewma {
    /// A responsive default (alpha = 0.25).
    fn default() -> Self {
        Ewma::new(0.25)
    }
}

impl Ewma {
    /// `alpha` in (0, 1]: weight of the newest sample.
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha {alpha} out of (0,1]");
        Ewma { alpha, value: None }
    }

    /// Fold one sample in.
    pub fn push(&mut self, x: f64) {
        self.value = Some(match self.value {
            None => x,
            Some(v) => v + self.alpha * (x - v),
        });
    }

    /// The current average (None before the first push).
    pub fn value(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive_formulas() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = RollingStats::new();
        for &x in &xs {
            s.push(x);
        }
        let mean: f64 = xs.iter().sum::<f64>() / xs.len() as f64;
        let var: f64 =
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((s.mean() - mean).abs() < 1e-12);
        assert!((s.stddev() - var.sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn empty_stats_are_safe() {
        let s = RollingStats::new();
        assert!(s.mean().is_nan());
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn single_sample_has_zero_stddev() {
        let mut s = RollingStats::new();
        s.push(42.0);
        assert_eq!(s.mean(), 42.0);
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn ewma_converges_to_constant_input() {
        let mut e = Ewma::new(0.3);
        for _ in 0..100 {
            e.push(5.0);
        }
        assert!((e.value().unwrap() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn ewma_tracks_level_shift() {
        let mut e = Ewma::new(0.5);
        for _ in 0..10 {
            e.push(0.0);
        }
        for _ in 0..20 {
            e.push(10.0);
        }
        assert!((e.value().unwrap() - 10.0).abs() < 0.1);
    }

    #[test]
    #[should_panic]
    fn ewma_rejects_bad_alpha() {
        Ewma::new(0.0);
    }
}
