//! The `perf_event`-analog profiler (paper §3.1).
//!
//! The paper profiles the JIT-ed program with Linux `perf_event`, reading
//! hardware counters (CPU cycles, cache misses, branch misses, page
//! faults) at a run-time overhead of up to 20 %, and uses CPU cycles as
//! the sole metric deciding which function to off-load.  This module is
//! that stack, built against the simulated platform:
//!
//! - [`counters`] — the counter set and the synthetic counter sources
//!   (derived from the cost model, like the real ones derive from the
//!   silicon);
//! - [`stats`] — rolling statistics (mean / stddev / EWMA) over samples;
//! - [`sampler`] — the sampling engine: per-function profiles, counter
//!   multiplexing, the ≤20 % measurement overhead, and the periodic
//!   analysis bursts that the paper calls out as the cause of the larger
//!   standard deviations under VPE (Table 1 caption, Fig 3c peak);
//! - [`hotspot`] — cycle-share ranking and hot-function detection, with
//!   system calls excluded (paper §3: "system calls are automatically
//!   excluded from the analysis").

pub mod counters;
pub mod hotspot;
pub mod multiplex;
pub mod sampler;
pub mod stats;

pub use counters::{CounterKind, CounterSample};
pub use hotspot::HotspotDetector;
pub use sampler::{PerfSampler, SamplerConfig};
pub use stats::{Ewma, RollingStats};
