//! Hot-function detection: rank functions by cycle share and pick the
//! off-load candidate (paper §3.1 — "the number of CPU cycles requested
//! for its execution" is the sole selection metric).

use crate::jit::module::{FunctionId, IrModule};

use super::sampler::PerfSampler;

/// Configuration for the detector.
#[derive(Debug, Clone, Copy)]
pub struct HotspotDetector {
    /// Minimum profiled calls before a function can be nominated (the
    /// warm-up the paper describes).
    pub min_samples: u64,
    /// Minimum share of total cycles (0..1) to count as "hot".
    pub share_threshold: f64,
}

impl Default for HotspotDetector {
    fn default() -> Self {
        HotspotDetector { min_samples: 5, share_threshold: 0.10 }
    }
}

/// A nomination produced by the detector.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hotspot {
    /// The nominated function.
    pub function: FunctionId,
    /// Share of all profiled cycles attributed to this function.
    pub cycle_share: f64,
}

impl HotspotDetector {
    /// The hottest eligible function satisfying `pred`, in one linear
    /// pass (this sits on the coordinator's retire hot path, which
    /// nominates per retired call — the coordinator passes a
    /// "still resident on the host" predicate so that, with N targets,
    /// several functions can each be moved to their own unit).
    ///
    /// System calls are excluded (paper §3: "system calls are
    /// automatically excluded from the analysis"), as are functions with
    /// fewer than `min_samples` profiled calls or below the share
    /// threshold.
    pub fn hottest_where<F: Fn(FunctionId) -> bool>(
        &self,
        sampler: &PerfSampler,
        module: &IrModule,
        pred: F,
    ) -> Option<Hotspot> {
        let total = sampler.total_cycles();
        if total == 0 {
            return None;
        }
        sampler
            .profiles()
            .filter(|(f, p)| {
                p.calls >= self.min_samples
                    && module.function(*f).map(|irf| !irf.is_syscall).unwrap_or(false)
                    && pred(*f)
            })
            .map(|(f, p)| Hotspot {
                function: f,
                cycle_share: p.total_cycles as f64 / total as f64,
            })
            .filter(|h| h.cycle_share >= self.share_threshold)
            .max_by(|a, b| a.cycle_share.total_cmp(&b.cycle_share))
    }

    /// The hottest eligible function, if any.
    pub fn hottest(&self, sampler: &PerfSampler, module: &IrModule) -> Option<Hotspot> {
        self.hottest_where(sampler, module, |_| true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jit::module::IrFunction;
    use crate::platform::TargetId;
    use crate::profiler::counters::CounterSample;
    use crate::profiler::sampler::SamplerConfig;
    use crate::sim::SimRng;

    fn setup() -> (PerfSampler, IrModule, SimRng) {
        let mut m = IrModule::new("test");
        m.add_function(IrFunction::user("hot", None));
        m.add_function(IrFunction::user("cold", None));
        m.add_function(IrFunction::syscall("write"));
        (
            PerfSampler::new(SamplerConfig::default()).unwrap(),
            m,
            SimRng::seeded(1),
        )
    }

    fn cycles(c: u64) -> CounterSample {
        CounterSample { cycles: c, ..Default::default() }
    }

    #[test]
    fn picks_the_dominant_function() {
        let (mut s, m, mut rng) = setup();
        for _ in 0..10 {
            s.record(FunctionId(0), TargetId::HOST, cycles(1000), 10, &mut rng);
            s.record(FunctionId(1), TargetId::HOST, cycles(10), 10, &mut rng);
        }
        let h = HotspotDetector::default().hottest(&s, &m).unwrap();
        assert_eq!(h.function, FunctionId(0));
        assert!(h.cycle_share > 0.9);
    }

    #[test]
    fn syscalls_are_never_nominated() {
        let (mut s, m, mut rng) = setup();
        // The syscall dominates the cycle count...
        for _ in 0..10 {
            s.record(FunctionId(2), TargetId::HOST, cycles(10_000), 10, &mut rng);
            s.record(FunctionId(0), TargetId::HOST, cycles(100), 10, &mut rng);
        }
        // ...but the user function is picked.
        let h = HotspotDetector { share_threshold: 0.0, ..Default::default() }
            .hottest(&s, &m)
            .unwrap();
        assert_eq!(h.function, FunctionId(0));
    }

    #[test]
    fn respects_min_samples_warmup() {
        let (mut s, m, mut rng) = setup();
        for _ in 0..3 {
            s.record(FunctionId(0), TargetId::HOST, cycles(1000), 10, &mut rng);
        }
        let d = HotspotDetector { min_samples: 5, share_threshold: 0.0 };
        assert!(d.hottest(&s, &m).is_none());
        for _ in 0..2 {
            s.record(FunctionId(0), TargetId::HOST, cycles(1000), 10, &mut rng);
        }
        assert!(d.hottest(&s, &m).is_some());
    }

    #[test]
    fn empty_profiles_yield_nothing() {
        let (s, m, _) = setup();
        assert!(HotspotDetector::default().hottest(&s, &m).is_none());
    }

    #[test]
    fn share_threshold_filters_lukewarm_functions() {
        let (mut s, m, mut rng) = setup();
        for _ in 0..10 {
            s.record(FunctionId(0), TargetId::HOST, cycles(100), 10, &mut rng);
            s.record(FunctionId(1), TargetId::HOST, cycles(100), 10, &mut rng);
        }
        // Both at ~50%: a 60% threshold nominates neither.
        let d = HotspotDetector { min_samples: 1, share_threshold: 0.6 };
        assert!(d.hottest(&s, &m).is_none());
    }
}
