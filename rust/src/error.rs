//! Crate-wide error type.

use std::fmt;

/// Errors surfaced by the VPE library.
#[derive(Debug)]
pub enum Error {
    /// PJRT / XLA failure (compile, execute, literal conversion).
    #[cfg(feature = "pjrt")]
    Xla(xla::Error),
    /// Filesystem problem while loading artifacts.
    Io(std::io::Error),
    /// Manifest / JSON parsing problem.
    Parse(String),
    /// An artifact referenced by name does not exist / does not match.
    Artifact(String),
    /// Invalid configuration.
    Config(String),
    /// Platform-model violation (unknown target, failed target, OOM in
    /// the shared region, ...).
    Platform(String),
    /// Coordinator-level invariant violation (unknown function id, ...).
    Coordinator(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            #[cfg(feature = "pjrt")]
            Error::Xla(e) => write!(f, "xla error: {e}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Platform(m) => write!(f, "platform error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            #[cfg(feature = "pjrt")]
            Error::Xla(e) => Some(e),
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
