//! Integration tests: the whole coordinator against the simulated
//! platform, plus (when `make artifacts` has run) the real PJRT path.

use vpe::coordinator::policy::AlwaysOffloadPolicy;
use vpe::coordinator::{Vpe, VpeConfig};
use vpe::platform::TargetId;
use vpe::profiler::sampler::SamplerConfig;
use vpe::workloads::WorkloadKind;

fn artifacts_present() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

// ---------------------------------------------------------------------------
// Simulation-level stories (always run)
// ---------------------------------------------------------------------------

#[test]
fn every_workload_reaches_the_paper_verdict() {
    // 5 workloads end on the DSP; the FFT is tried and reverted.
    for kind in WorkloadKind::ALL {
        let mut v = Vpe::new(VpeConfig::sim_only()).unwrap();
        let f = v.register_workload(kind).unwrap();
        v.run(f, 25).unwrap();
        let want = if kind == WorkloadKind::Fft {
            TargetId::ArmCore
        } else {
            TargetId::C64xDsp
        };
        assert_eq!(v.current_target(f).unwrap(), want, "{kind:?}");
        assert_eq!(v.events().offloads().len(), 1, "{kind:?} must be tried once");
    }
}

#[test]
fn hotspot_is_chosen_among_competing_functions() {
    // An app with a heavy matmul and a light dotprod: the matmul is
    // offloaded first (it dominates the cycle counts).
    let mut v = Vpe::new(VpeConfig::sim_only()).unwrap();
    let mm = v.register_matmul(500).unwrap();
    let dot = v.register_workload(WorkloadKind::Dotprod).unwrap();
    for _ in 0..8 {
        v.call(mm).unwrap();
        v.call(dot).unwrap();
    }
    assert_eq!(v.current_target(mm).unwrap(), TargetId::C64xDsp);
    let first_offload = v.events().offloads()[0].1;
    assert_eq!(first_offload, mm, "matmul must be nominated first");
}

#[test]
fn syscalls_are_registered_but_never_offloaded() {
    let mut v = Vpe::new(VpeConfig::sim_only()).unwrap();
    let _write = v.register_syscall("write").unwrap();
    let mm = v.register_workload(WorkloadKind::Matmul).unwrap();
    v.run(mm, 20).unwrap();
    // Only the user function shows up in offloads.
    for (_, f, _) in v.events().offloads() {
        assert_eq!(f, mm);
    }
}

#[test]
fn degraded_dsp_changes_the_verdict() {
    // A 40x-degraded DSP makes even the matmul not worth offloading:
    // VPE tries it, observes, and reverts — adaptivity beyond the
    // paper's static table.
    let mut v = Vpe::new(VpeConfig::sim_only()).unwrap();
    v.soc_mut().degrade_target(TargetId::C64xDsp, 40.0);
    let f = v.register_matmul(500).unwrap();
    v.run(f, 25).unwrap();
    assert_eq!(v.current_target(f).unwrap(), TargetId::ArmCore);
    assert_eq!(v.events().reverts().len(), 1);
}

#[test]
fn clock_accumulates_warmup_plus_steady_state() {
    let mut v = Vpe::new(VpeConfig::sim_only()).unwrap();
    let f = v.register_matmul(500).unwrap();
    let recs = v.run(f, 10).unwrap();
    let total: u64 = recs.iter().map(|r| r.total_ns()).sum();
    assert_eq!(v.clock().now_ns(), total, "clock must equal the sum of call costs");
}

#[test]
fn shared_region_is_clean_after_a_run() {
    let mut v = Vpe::new(VpeConfig::sim_only()).unwrap();
    let f = v.register_workload(WorkloadKind::Conv2d).unwrap();
    v.run(f, 30).unwrap();
    assert_eq!(v.soc().shared.used_bytes(), 0, "staged parameter blocks leaked");
    assert!(v.soc().shared.alloc_count() > 0, "offloaded calls must stage params");
}

#[test]
fn always_offload_never_recovers_from_fft() {
    // Ablation: without the observe/revert loop the FFT stays 0.7x
    // forever — the paper's §5.2 argument for VPE's dynamism.
    let mut cfg = VpeConfig::sim_only();
    cfg.sampler = SamplerConfig::default();
    let mut v = Vpe::with_policy(cfg, Box::new(AlwaysOffloadPolicy)).unwrap();
    let f = v.register_workload(WorkloadKind::Fft).unwrap();
    v.run(f, 25).unwrap();
    assert_eq!(v.current_target(f).unwrap(), TargetId::C64xDsp);
    assert!(v.events().reverts().is_empty());
}

// ---------------------------------------------------------------------------
// Real-artifact stories (skip when artifacts are absent)
// ---------------------------------------------------------------------------

#[test]
fn all_artifacts_load_and_verify_against_rust_references() {
    if !artifacts_present() {
        eprintln!("skipped: run `make artifacts`");
        return;
    }
    let store = vpe::runtime::ArtifactStore::open_default().unwrap();
    // Every workload, both builds, must produce the Rust reference's
    // output at the artifact shape.
    for kind in WorkloadKind::ALL {
        let inst = vpe::workloads::instance(kind, 0xABCD);
        for name in [&inst.artifact_naive, &inst.artifact_dsp] {
            let a = store.load(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            let (out, _) = a.execute(&inst.inputs).unwrap();
            let tol = if kind == WorkloadKind::Fft { 0.1 } else { 0.0 };
            assert!(
                inst.expected.allclose(&out, tol),
                "{name}: output does not match the Rust reference"
            );
        }
    }
}

#[test]
fn matmul_artifacts_cover_all_aot_sizes() {
    if !artifacts_present() {
        eprintln!("skipped: run `make artifacts`");
        return;
    }
    let store = vpe::runtime::ArtifactStore::open_default().unwrap();
    for n in vpe::workloads::shapes::MATMUL_SIZES {
        let inst = vpe::workloads::matmul::instance(n, 7);
        for name in [&inst.artifact_naive, &inst.artifact_dsp] {
            let a = store.load(name).unwrap();
            let (out, _) = a.execute(&inst.inputs).unwrap();
            assert!(inst.expected.allclose(&out, 0.0), "{name}");
        }
    }
}

#[test]
fn full_lifecycle_with_real_execution() {
    if !artifacts_present() {
        eprintln!("skipped: run `make artifacts`");
        return;
    }
    let mut v = Vpe::new(VpeConfig::default()).unwrap();
    let f = v.register_workload(WorkloadKind::Conv2d).unwrap();
    let recs = v.run(f, 15).unwrap();
    // Both the naive build (warm-up on ARM) and the Pallas build
    // (steady state on DSP) really executed and verified.
    assert!(recs.iter().all(|r| r.output_ok == Some(true)));
    assert!(recs.iter().any(|r| r.target == TargetId::ArmCore));
    assert!(recs.iter().any(|r| r.target == TargetId::C64xDsp));
    assert_eq!(v.mismatch_count(f), 0);
}

#[test]
fn call_with_runs_custom_inputs_through_the_current_target() {
    if !artifacts_present() {
        eprintln!("skipped: run `make artifacts`");
        return;
    }
    let mut v = Vpe::new(VpeConfig::default()).unwrap();
    let f = v.register_workload(WorkloadKind::Conv2d).unwrap();
    let h = vpe::workloads::shapes::CONV_H;
    let w = vpe::workloads::shapes::CONV_W;
    let img = vpe::workloads::generator::ints(h * w, -8, 8, 99);
    let ker = vpe::workloads::conv2d::laplacian3();
    let want = vpe::workloads::conv2d::reference(&img, h, w, &ker, 3);
    let inputs = [
        vpe::workloads::Tensor::i32(vec![h, w], img),
        vpe::workloads::Tensor::i32(vec![3, 3], ker),
    ];
    // Before and after the offload the same inputs give the same output.
    let (_, out1) = v.call_with(f, &inputs).unwrap();
    for _ in 0..12 {
        v.call(f).unwrap();
    }
    assert_eq!(v.current_target(f).unwrap(), TargetId::C64xDsp);
    let (rec2, out2) = v.call_with(f, &inputs).unwrap();
    assert_eq!(rec2.target, TargetId::C64xDsp);
    assert_eq!(out1.unwrap().as_i32().unwrap(), want.as_slice());
    assert_eq!(out2.unwrap().as_i32().unwrap(), want.as_slice());
}

// ---------------------------------------------------------------------------
// Input-pattern discontinuities (paper §3: VPE "can revise its decisions")
// ---------------------------------------------------------------------------

#[test]
fn input_discontinuity_reopens_a_blacklisted_decision() {
    // Small matrices: the 100 ms setup makes the DSP lose, VPE reverts.
    // Then the caller's matrices grow 500x in work: with retry_after the
    // policy re-trials and commits to the DSP.
    let mut cfg = VpeConfig::sim_only();
    cfg.blind.retry_after = Some(8);
    let mut v = Vpe::new(cfg).unwrap();
    let f = v.register_matmul(40).unwrap(); // ARM ~8.4 ms, DSP ~100 ms
    v.run(f, 18).unwrap();
    assert_eq!(v.current_target(f).unwrap(), TargetId::ArmCore, "small: must revert");
    let reverts_small = v.events().reverts().len();
    assert!(reverts_small >= 1, "at least one failed trial");

    // The input pattern changes: same function, 500x500 matrices.
    v.set_scale(f, vpe::workloads::matmul_scale(500)).unwrap();
    v.run(f, 30).unwrap();
    assert_eq!(
        v.current_target(f).unwrap(),
        TargetId::C64xDsp,
        "large: the re-trial must commit"
    );
    assert!(
        v.events().offloads().len() > reverts_small,
        "a fresh trial happened after the discontinuity"
    );
    assert_eq!(v.events().reverts().len(), reverts_small, "the new trial succeeded");
}

#[test]
fn without_retry_the_decision_stays_stale() {
    // Ablation for the test above: the paper's plain blind offload with
    // permanent blacklisting misses the input change.
    let mut v = Vpe::new(VpeConfig::sim_only()).unwrap();
    let f = v.register_matmul(40).unwrap();
    v.run(f, 20).unwrap();
    v.set_scale(f, vpe::workloads::matmul_scale(500)).unwrap();
    v.run(f, 30).unwrap();
    assert_eq!(v.current_target(f).unwrap(), TargetId::ArmCore, "stale verdict persists");
}
