//! Integration tests: the whole coordinator against the simulated
//! platform, plus (when `make artifacts` has run) the real PJRT path.

use vpe::coordinator::policy::AlwaysOffloadPolicy;
use vpe::coordinator::{Vpe, VpeConfig};
use vpe::platform::{dm3730, TargetId};
use vpe::profiler::sampler::SamplerConfig;
use vpe::workloads::WorkloadKind;

#[cfg(feature = "pjrt")]
fn artifacts_present() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

// ---------------------------------------------------------------------------
// Simulation-level stories (always run)
// ---------------------------------------------------------------------------

#[test]
fn every_workload_reaches_the_paper_verdict() {
    // 5 workloads end on the DSP; the FFT is tried and reverted.
    for kind in WorkloadKind::ALL {
        let mut v = Vpe::new(VpeConfig::sim_only()).unwrap();
        let f = v.register_workload(kind).unwrap();
        v.run(f, 25).unwrap();
        let want = if kind == WorkloadKind::Fft {
            TargetId::HOST
        } else {
            dm3730::DSP
        };
        assert_eq!(v.current_target(f).unwrap(), want, "{kind:?}");
        assert_eq!(v.events().offloads().len(), 1, "{kind:?} must be tried once");
    }
}

#[test]
fn hotspot_is_chosen_among_competing_functions() {
    // An app with a heavy matmul and a light dotprod: the matmul is
    // offloaded first (it dominates the cycle counts).
    let mut v = Vpe::new(VpeConfig::sim_only()).unwrap();
    let mm = v.register_matmul(500).unwrap();
    let dot = v.register_workload(WorkloadKind::Dotprod).unwrap();
    for _ in 0..8 {
        v.call(mm).unwrap();
        v.call(dot).unwrap();
    }
    assert_eq!(v.current_target(mm).unwrap(), dm3730::DSP);
    let first_offload = v.events().offloads()[0].1;
    assert_eq!(first_offload, mm, "matmul must be nominated first");
}

#[test]
fn syscalls_are_registered_but_never_offloaded() {
    let mut v = Vpe::new(VpeConfig::sim_only()).unwrap();
    let _write = v.register_syscall("write").unwrap();
    let mm = v.register_workload(WorkloadKind::Matmul).unwrap();
    v.run(mm, 20).unwrap();
    // Only the user function shows up in offloads.
    for (_, f, _) in v.events().offloads() {
        assert_eq!(f, mm);
    }
}

#[test]
fn degraded_dsp_changes_the_verdict() {
    // A 40x-degraded DSP makes even the matmul not worth offloading:
    // VPE tries it, observes, and reverts — adaptivity beyond the
    // paper's static table.
    let mut v = Vpe::new(VpeConfig::sim_only()).unwrap();
    v.soc_mut().degrade_target(dm3730::DSP, 40.0);
    let f = v.register_matmul(500).unwrap();
    v.run(f, 25).unwrap();
    assert_eq!(v.current_target(f).unwrap(), TargetId::HOST);
    assert_eq!(v.events().reverts().len(), 1);
}

#[test]
fn clock_accumulates_warmup_plus_steady_state() {
    let mut v = Vpe::new(VpeConfig::sim_only()).unwrap();
    let f = v.register_matmul(500).unwrap();
    let recs = v.run(f, 10).unwrap();
    let total: u64 = recs.iter().map(|r| r.total_ns()).sum();
    assert_eq!(v.clock().now_ns(), total, "clock must equal the sum of call costs");
}

#[test]
fn shared_region_is_clean_after_a_run() {
    let mut v = Vpe::new(VpeConfig::sim_only()).unwrap();
    let f = v.register_workload(WorkloadKind::Conv2d).unwrap();
    v.run(f, 30).unwrap();
    assert_eq!(v.soc().shared.used_bytes(), 0, "staged parameter blocks leaked");
    assert!(v.soc().shared.alloc_count() > 0, "offloaded calls must stage params");
}

#[test]
fn always_offload_never_recovers_from_fft() {
    // Ablation: without the observe/revert loop the FFT stays 0.7x
    // forever — the paper's §5.2 argument for VPE's dynamism.
    let mut cfg = VpeConfig::sim_only();
    cfg.sampler = SamplerConfig::default();
    let mut v = Vpe::with_policy(cfg, Box::new(AlwaysOffloadPolicy)).unwrap();
    let f = v.register_workload(WorkloadKind::Fft).unwrap();
    v.run(f, 25).unwrap();
    assert_eq!(v.current_target(f).unwrap(), dm3730::DSP);
    assert!(v.events().reverts().is_empty());
}

// ---------------------------------------------------------------------------
// Real-artifact stories (skip when artifacts are absent)
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
#[test]
fn all_artifacts_load_and_verify_against_rust_references() {
    if !artifacts_present() {
        eprintln!("skipped: run `make artifacts`");
        return;
    }
    let store = vpe::runtime::ArtifactStore::open_default().unwrap();
    // Every workload, both builds, must produce the Rust reference's
    // output at the artifact shape.
    for kind in WorkloadKind::ALL {
        let inst = vpe::workloads::instance(kind, 0xABCD);
        for name in [&inst.artifact_naive, &inst.artifact_dsp] {
            let a = store.load(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            let (out, _) = a.execute(&inst.inputs).unwrap();
            let tol = if kind == WorkloadKind::Fft { 0.1 } else { 0.0 };
            assert!(
                inst.expected.allclose(&out, tol),
                "{name}: output does not match the Rust reference"
            );
        }
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn matmul_artifacts_cover_all_aot_sizes() {
    if !artifacts_present() {
        eprintln!("skipped: run `make artifacts`");
        return;
    }
    let store = vpe::runtime::ArtifactStore::open_default().unwrap();
    for n in vpe::workloads::shapes::MATMUL_SIZES {
        let inst = vpe::workloads::matmul::instance(n, 7);
        for name in [&inst.artifact_naive, &inst.artifact_dsp] {
            let a = store.load(name).unwrap();
            let (out, _) = a.execute(&inst.inputs).unwrap();
            assert!(inst.expected.allclose(&out, 0.0), "{name}");
        }
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn full_lifecycle_with_real_execution() {
    if !artifacts_present() {
        eprintln!("skipped: run `make artifacts`");
        return;
    }
    let mut v = Vpe::new(VpeConfig::default()).unwrap();
    let f = v.register_workload(WorkloadKind::Conv2d).unwrap();
    let recs = v.run(f, 15).unwrap();
    // Both the naive build (warm-up on ARM) and the Pallas build
    // (steady state on DSP) really executed and verified.
    assert!(recs.iter().all(|r| r.output_ok == Some(true)));
    assert!(recs.iter().any(|r| r.target == TargetId::HOST));
    assert!(recs.iter().any(|r| r.target == dm3730::DSP));
    assert_eq!(v.mismatch_count(f), 0);
}

#[cfg(feature = "pjrt")]
#[test]
fn call_with_runs_custom_inputs_through_the_current_target() {
    if !artifacts_present() {
        eprintln!("skipped: run `make artifacts`");
        return;
    }
    let mut v = Vpe::new(VpeConfig::default()).unwrap();
    let f = v.register_workload(WorkloadKind::Conv2d).unwrap();
    let h = vpe::workloads::shapes::CONV_H;
    let w = vpe::workloads::shapes::CONV_W;
    let img = vpe::workloads::generator::ints(h * w, -8, 8, 99);
    let ker = vpe::workloads::conv2d::laplacian3();
    let want = vpe::workloads::conv2d::reference(&img, h, w, &ker, 3);
    let inputs = [
        vpe::workloads::Tensor::i32(vec![h, w], img),
        vpe::workloads::Tensor::i32(vec![3, 3], ker),
    ];
    // Before and after the offload the same inputs give the same output.
    let (_, out1) = v.call_with(f, &inputs).unwrap();
    for _ in 0..12 {
        v.call(f).unwrap();
    }
    assert_eq!(v.current_target(f).unwrap(), dm3730::DSP);
    let (rec2, out2) = v.call_with(f, &inputs).unwrap();
    assert_eq!(rec2.target, dm3730::DSP);
    assert_eq!(out1.unwrap().as_i32().unwrap(), want.as_slice());
    assert_eq!(out2.unwrap().as_i32().unwrap(), want.as_slice());
}

// ---------------------------------------------------------------------------
// Input-pattern discontinuities (paper §3: VPE "can revise its decisions")
// ---------------------------------------------------------------------------

#[test]
fn input_discontinuity_reopens_a_blacklisted_decision() {
    // Small matrices: the 100 ms setup makes the DSP lose, VPE reverts.
    // Then the caller's matrices grow 500x in work: with retry_after the
    // policy re-trials and commits to the DSP.
    let mut cfg = VpeConfig::sim_only();
    cfg.blind.retry_after = Some(8);
    let mut v = Vpe::new(cfg).unwrap();
    let f = v.register_matmul(40).unwrap(); // ARM ~8.4 ms, DSP ~100 ms
    v.run(f, 18).unwrap();
    assert_eq!(v.current_target(f).unwrap(), TargetId::HOST, "small: must revert");
    let reverts_small = v.events().reverts().len();
    assert!(reverts_small >= 1, "at least one failed trial");

    // The input pattern changes: same function, 500x500 matrices.
    v.set_scale(f, vpe::workloads::matmul_scale(500)).unwrap();
    v.run(f, 30).unwrap();
    assert_eq!(
        v.current_target(f).unwrap(),
        dm3730::DSP,
        "large: the re-trial must commit"
    );
    assert!(
        v.events().offloads().len() > reverts_small,
        "a fresh trial happened after the discontinuity"
    );
    assert_eq!(v.events().reverts().len(), reverts_small, "the new trial succeeded");
}

// ---------------------------------------------------------------------------
// N-target registry + concurrent dispatch queue (the multi-unit refactor)
// ---------------------------------------------------------------------------

#[test]
fn functions_spread_across_three_units_by_data_alone() {
    // Register two extra units as pure data (spec + cost rows) and make
    // each unit the best home for a different workload: the unchanged
    // policy/coordinator must route each function to its own unit.
    use vpe::platform::{TargetSpec, TransferModel, Transport};
    let mut cfg = VpeConfig::sim_only();
    // The matmul dominates total cycles; lower the share gate so the
    // cooler functions still get their nomination.
    cfg.detector.share_threshold = 0.02;
    let mut v = Vpe::new(cfg).unwrap();
    let neon = v.soc_mut().add_target(
        TargetSpec::new("NEON-class vector unit", 1_000_000_000)
            .with_issue_width(4)
            .with_transport(Transport::SharedMemory(TransferModel {
                dispatch_fixed_ns: 5_000_000,
                per_param_byte_ns: 1.0,
            })),
    );
    let gpu = v.soc_mut().add_target(
        TargetSpec::new("GPU-class accelerator", 1_200_000_000)
            .with_issue_width(32)
            .with_transport(Transport::SharedMemory(TransferModel {
                dispatch_fixed_ns: 30_000_000,
                per_param_byte_ns: 1.0,
            })),
    );
    // NEON: great at conv2d, mediocre at matmul. GPU: great at matmul.
    v.soc_mut().cost.set_rate(WorkloadKind::Conv2d, neon, 0.05);
    v.soc_mut().cost.set_rate(WorkloadKind::Matmul, neon, 3.0);
    v.soc_mut().cost.set_rate(WorkloadKind::Matmul, gpu, 0.2);
    let mm = v.register_matmul(500).unwrap();
    let conv = v.register_workload(WorkloadKind::Conv2d).unwrap();
    let dot = v.register_workload(WorkloadKind::Dotprod).unwrap();
    for _ in 0..30 {
        v.call(mm).unwrap();
        v.call(conv).unwrap();
        v.call(dot).unwrap();
    }
    assert_eq!(v.current_target(mm).unwrap(), gpu, "matmul belongs on the GPU-class unit");
    assert_eq!(v.current_target(conv).unwrap(), neon, "conv2d belongs on the vector unit");
    assert_eq!(v.current_target(dot).unwrap(), dm3730::DSP, "dotprod keeps the DSP");
}

#[test]
fn queued_dispatches_overlap_and_retire_exactly_once() {
    let mut v = Vpe::new(VpeConfig::sim_only()).unwrap();
    let mm = v.register_matmul(500).unwrap();
    let fft = v.register_workload(WorkloadKind::Fft).unwrap();
    for _ in 0..10 {
        v.call(mm).unwrap();
        v.call(fft).unwrap();
    }
    assert_eq!(v.current_target(mm).unwrap(), dm3730::DSP);
    assert_eq!(v.current_target(fft).unwrap(), TargetId::HOST);
    // Issue a burst without waiting, then drain.
    let mut tickets = Vec::new();
    for _ in 0..3 {
        tickets.push(v.submit(mm).unwrap());
        tickets.push(v.submit(fft).unwrap());
    }
    assert_eq!(v.in_flight(), 6);
    let recs = v.drain().unwrap();
    assert_eq!(recs.len(), tickets.len(), "every ticket retires exactly once");
    assert_eq!(v.in_flight(), 0);
    assert!(v.max_in_flight() >= 2, "dispatches must have been concurrent");
    // Per-target serialization: on each unit, execution windows are
    // disjoint and ordered.
    for unit in [TargetId::HOST, dm3730::DSP] {
        let mut on_unit: Vec<_> = recs.iter().filter(|r| r.target == unit).collect();
        on_unit.sort_by_key(|r| r.start_ns);
        for w in on_unit.windows(2) {
            assert!(w[1].start_ns >= w[0].complete_ns, "overlap on {unit}");
        }
    }
    // Cross-target concurrency really happened.
    let dsp = recs.iter().find(|r| r.target == dm3730::DSP).unwrap();
    let host = recs.iter().find(|r| r.target == TargetId::HOST).unwrap();
    assert!(
        dsp.start_ns < host.complete_ns && host.start_ns < dsp.complete_ns,
        "windows on different units must overlap"
    );
}

#[cfg(not(feature = "pjrt"))]
#[test]
fn reference_backend_computes_and_verifies_numerics() {
    // Without PJRT, `artifacts_dir: Some(..)` selects the pure-Rust
    // reference backend: every call really computes and verifies.
    let mut v = Vpe::new(VpeConfig::default()).unwrap();
    assert_eq!(v.backend_name(), "reference");
    let f = v.register_workload(WorkloadKind::Conv2d).unwrap();
    let recs = v.run(f, 12).unwrap();
    assert!(recs.iter().all(|r| r.output_ok == Some(true)));
    assert!(recs.iter().all(|r| r.wall.is_some()));
    assert_eq!(v.mismatch_count(f), 0);
}

#[test]
fn without_retry_the_decision_stays_stale() {
    // Ablation for the test above: the paper's plain blind offload with
    // permanent blacklisting misses the input change.
    let mut v = Vpe::new(VpeConfig::sim_only()).unwrap();
    let f = v.register_matmul(40).unwrap();
    v.run(f, 20).unwrap();
    v.set_scale(f, vpe::workloads::matmul_scale(500)).unwrap();
    v.run(f, 30).unwrap();
    assert_eq!(v.current_target(f).unwrap(), TargetId::HOST, "stale verdict persists");
}
