//! Property-based tests over the coordinator's substrates (in-tree
//! `util::prop` driver, 100+ random cases per property).

use vpe::coordinator::decision_tree::{DecisionTree, Observation};
use vpe::jit::module::{FunctionId, IrFunction, IrModule};
use vpe::jit::wrapper::DispatchTable;
use vpe::platform::memory::SharedRegion;
use vpe::platform::{dm3730, CostModel, Soc, TargetId};
use vpe::profiler::stats::RollingStats;
use vpe::util::prop::{self, assert_prop};
use vpe::workloads::WorkloadKind;

// ---------------------------------------------------------------------------
// Shared-memory allocator
// ---------------------------------------------------------------------------

#[test]
fn prop_allocations_never_overlap_and_free_restores() {
    prop::check("shared-region random alloc/free", 150, |g| {
        let mut region = SharedRegion::new(1 << 16, 64).expect("region");
        let mut live: Vec<vpe::platform::memory::Allocation> = Vec::new();
        for _ in 0..g.usize_in(5, 60) {
            if !live.is_empty() && g.bool() {
                let idx = g.usize_in(0, live.len());
                let a = live.swap_remove(idx);
                region.free(a).map_err(|e| e.to_string())?;
            } else {
                let size = g.u64_in(1, 4096);
                if let Ok(a) = region.alloc(size) {
                    // overlap check against every live allocation
                    for b in &live {
                        let disjoint =
                            a.offset + a.size <= b.offset || b.offset + b.size <= a.offset;
                        assert_prop(disjoint, format!("{a:?} overlaps {b:?}"))?;
                    }
                    live.push(a);
                }
            }
            let live_sum: u64 = live.iter().map(|a| a.size).sum();
            assert_prop(
                region.used_bytes() == live_sum,
                format!("used {} != live {}", region.used_bytes(), live_sum),
            )?;
        }
        // Free everything: the region must coalesce back to one block.
        for a in live.drain(..) {
            region.free(a).map_err(|e| e.to_string())?;
        }
        assert_prop(region.used_bytes() == 0, "leak")?;
        assert_prop(region.largest_free() == 1 << 16, "fragmentation remains")
    });
}

// ---------------------------------------------------------------------------
// Cost model
// ---------------------------------------------------------------------------

#[test]
fn prop_cost_model_is_monotone_in_items() {
    let model = CostModel::default();
    let kinds = WorkloadKind::ALL;
    prop::check("exec_ns monotone", 200, |g| {
        let kind = *g.choose(&kinds);
        let a = g.u64_in(1, 1 << 28) as f64;
        let b = a + g.u64_in(1, 1 << 20) as f64;
        for t in [dm3730::ARM, dm3730::DSP] {
            assert_prop(
                model.exec_ns(kind, a, t) < model.exec_ns(kind, b, t),
                format!("{kind:?}/{t:?}: not monotone at {a}->{b}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_dsp_dispatch_overhead_always_charged() {
    let soc = Soc::dm3730();
    let kinds = WorkloadKind::ALL;
    prop::check("remote call >= setup", 200, |g| {
        let kind = *g.choose(&kinds);
        let items = g.u64_in(1, 1 << 24) as f64;
        let bytes = g.u64_in(0, 4096);
        let dsp = soc.call_ns(kind, items, bytes, dm3730::DSP).expect("dsp healthy");
        let setup = soc.transfer.dispatch_ns(bytes);
        assert_prop(dsp >= setup, format!("dsp {dsp} < setup {setup}"))
    });
}

// ---------------------------------------------------------------------------
// Dispatch table
// ---------------------------------------------------------------------------

#[test]
fn prop_dispatch_table_tracks_last_write() {
    prop::check("dispatch slots independent", 100, |g| {
        let n = g.usize_in(1, 32);
        let mut m = IrModule::new("p");
        for i in 0..n {
            m.add_function(IrFunction::user(&format!("f{i}"), None));
        }
        m.finalize();
        let table = DispatchTable::for_module(&m).expect("table");
        let mut expected = vec![dm3730::ARM; n];
        for _ in 0..g.usize_in(1, 80) {
            let f = g.usize_in(0, n);
            let t = if g.bool() { dm3730::DSP } else { dm3730::ARM };
            table.set_target(FunctionId(f as u32), t).expect("set");
            expected[f] = t;
            // Every slot must read back its own last write.
            for (i, want) in expected.iter().enumerate() {
                let got = table.current_target(FunctionId(i as u32)).expect("get");
                assert_prop(got == *want, format!("slot {i}: {got:?} != {want:?}"))?;
            }
        }
        let offloaded: Vec<usize> = expected
            .iter()
            .enumerate()
            .filter(|(_, t)| **t == dm3730::DSP)
            .map(|(i, _)| i)
            .collect();
        let got: Vec<usize> = table.offloaded().iter().map(|f| f.0 as usize).collect();
        assert_prop(got == offloaded, format!("offloaded {got:?} != {offloaded:?}"))
    });
}

// ---------------------------------------------------------------------------
// Rolling statistics
// ---------------------------------------------------------------------------

#[test]
fn prop_welford_matches_two_pass() {
    prop::check("welford == two-pass", 150, |g| {
        let n = g.usize_in(2, 200);
        let xs: Vec<f64> = (0..n).map(|_| g.f64_unit() * 1e6).collect();
        let mut s = RollingStats::new();
        for &x in &xs {
            s.push(x);
        }
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64;
        assert_prop((s.mean() - mean).abs() < 1e-6 * mean.abs().max(1.0), "mean mismatch")?;
        assert_prop(
            (s.stddev() - var.sqrt()).abs() < 1e-6 * var.sqrt().max(1.0),
            "stddev mismatch",
        )
    });
}

// ---------------------------------------------------------------------------
// Decision tree
// ---------------------------------------------------------------------------

#[test]
fn prop_decision_tree_recovers_planted_threshold() {
    prop::check("tree finds planted cut", 60, |g| {
        let cut = 20.0 + g.f64_unit() * 400.0;
        let n = g.usize_in(40, 200);
        let obs: Vec<Observation> = (0..n)
            .map(|i| {
                let size = i as f64 * 500.0 / n as f64;
                Observation {
                    size,
                    best: if size <= cut { dm3730::ARM } else { dm3730::DSP },
                }
            })
            .collect();
        let tree = DecisionTree::fit(&obs, 6, 1);
        let acc = tree.accuracy(&obs);
        assert_prop(acc > 0.97, format!("cut {cut:.1}: accuracy {acc}"))
    });
}

#[test]
fn prop_decision_tree_never_panics_on_noise() {
    prop::check("tree total on random labels", 80, |g| {
        let n = g.usize_in(0, 60);
        let obs: Vec<Observation> = (0..n)
            .map(|_| Observation {
                size: g.f64_unit() * 1000.0,
                best: if g.bool() { dm3730::ARM } else { dm3730::DSP },
            })
            .collect();
        let tree = DecisionTree::fit(&obs, 4, 2);
        // Predictions are total over the whole domain.
        for _ in 0..10 {
            let _ = tree.predict(g.f64_unit() * 2000.0 - 500.0);
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Dispatch-queue invariants (the event-driven concurrent dispatch path)
// ---------------------------------------------------------------------------

/// A 4-unit coordinator (host + DSP + two data-registered units), every
/// workload priced everywhere, always-offload so remote units see load.
fn multi_target_vpe(seed: u64) -> (vpe::coordinator::Vpe, Vec<TargetId>) {
    multi_target_vpe_with(seed, 2, 8)
}

/// [`multi_target_vpe`] with explicit bounded-queue depth and batch
/// width caps (the batching property tests need room to coalesce).
fn multi_target_vpe_with(
    seed: u64,
    max_queue: usize,
    max_batch: usize,
) -> (vpe::coordinator::Vpe, Vec<TargetId>) {
    use vpe::coordinator::policy::AlwaysOffloadPolicy;
    use vpe::coordinator::VpeConfig;
    use vpe::platform::{TargetSpec, TransferModel, Transport};

    let mut cfg = VpeConfig::sim_only();
    cfg.seed = seed;
    cfg.max_queue_per_target = max_queue;
    cfg.max_batch_width = max_batch;
    let mut v = vpe::coordinator::Vpe::with_policy(cfg, Box::new(AlwaysOffloadPolicy))
        .expect("vpe");
    let mut targets = vec![dm3730::ARM, dm3730::DSP];
    for (name, fixed_ns) in [("unit-a", 3_000_000u64), ("unit-b", 9_000_000u64)] {
        let id = v.soc_mut().add_target(
            TargetSpec::new(name, 1_000_000_000).with_transport(Transport::SharedMemory(
                TransferModel { dispatch_fixed_ns: fixed_ns, per_param_byte_ns: 1.0 },
            )),
        );
        for kind in WorkloadKind::ALL {
            // Arbitrary but distinct per-unit rates.
            let host = v.soc().cost.rate_ns(kind, dm3730::ARM).expect("row");
            v.soc_mut().cost.set_rate(kind, id, host / (2.0 + id.0 as f64));
        }
        targets.push(id);
    }
    (v, targets)
}

#[test]
fn prop_queue_serializes_targets_and_retires_exactly_once() {
    prop::check("dispatch queue invariants", 60, |g| {
        let (mut v, targets) = multi_target_vpe(g.u64_in(0, u64::MAX - 1));
        let kinds = [WorkloadKind::Matmul, WorkloadKind::Dotprod, WorkloadKind::Conv2d];
        let mut fns = Vec::new();
        for kind in kinds {
            fns.push(v.register_workload(kind).expect("register"));
        }
        // Random interleaving of submits and partial drains.
        let mut submitted = 0u64;
        let mut records = Vec::new();
        for _ in 0..g.usize_in(5, 40) {
            if g.bool() {
                let f = *g.choose(&fns);
                v.submit(f).expect("submit");
                submitted += 1;
            } else {
                records.extend(v.drain().expect("drain"));
            }
        }
        records.extend(v.drain().expect("drain"));
        assert_prop(
            records.len() as u64 == submitted,
            format!("retired {} != submitted {submitted}", records.len()),
        )?;
        assert_prop(v.in_flight() == 0, "queue must be empty after a full drain")?;

        // No two dispatches overlap on one target; host order == issue
        // order (program order preserved on the fallback path).
        for &t in &targets {
            let mut on_t: Vec<_> = records.iter().filter(|r| r.target == t).collect();
            on_t.sort_by_key(|r| r.start_ns);
            for w in on_t.windows(2) {
                assert_prop(
                    w[1].start_ns >= w[0].complete_ns,
                    format!("overlap on {t}: {:?} then {:?}", w[0], w[1]),
                )?;
            }
            if t.is_host() {
                let mut by_issue = on_t.clone();
                by_issue.sort_by_key(|r| r.issue_ns);
                let issue_order: Vec<u64> = by_issue.iter().map(|r| r.start_ns).collect();
                let start_order: Vec<u64> = on_t.iter().map(|r| r.start_ns).collect();
                assert_prop(
                    issue_order == start_order,
                    "host dispatches must start in program order",
                )?;
            }
        }

        // The shared region never leaks staged parameter blocks.
        assert_prop(v.soc().shared.used_bytes() == 0, "staged params leaked")
    });
}

#[test]
fn prop_scheduler_free_at_matches_busy_until() {
    prop::check("free_at vs busy_until", 150, |g| {
        let mut s = vpe::coordinator::scheduler::TargetScheduler::new();
        let t = TargetId(g.u64_in(0, 4) as u16);
        let mut horizon = 0u64;
        for _ in 0..g.usize_in(1, 30) {
            let start = g.u64_in(0, 1 << 30);
            let dur = g.u64_in(1, 1 << 20);
            s.occupy(t, start, dur);
            horizon = horizon.max(start + dur);
            // free_at never reports a stale (past) timestamp...
            let now = g.u64_in(0, 1 << 31);
            let free = s.free_at(t, now);
            assert_prop(
                free == 0 || free > now,
                format!("free_at({now}) returned stale {free}"),
            )?;
            // ...and agrees with the raw busy-until mark.
            assert_prop(s.busy_until(t) == horizon, "busy_until drifted")?;
            if now < horizon {
                assert_prop(free == horizon, "mid-occupancy must report the horizon")?;
            } else {
                assert_prop(free == 0, "expired occupancy must report free")?;
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Sharded fan-out (split -> concurrent dispatch -> reassemble)
// ---------------------------------------------------------------------------

#[test]
fn prop_shard_reassembly_matches_reference_for_every_kind() {
    use vpe::workloads::{instance, reference_output, shard, Tensor};
    let kinds: Vec<WorkloadKind> = WorkloadKind::ALL
        .into_iter()
        .filter(|k| shard::shardable(*k))
        .collect();
    prop::check("shard/reassemble == full reference", 25, |g| {
        let kind = *g.choose(&kinds);
        let w = instance(kind, g.u64_in(0, 1 << 20));
        let units = shard::shard_units(kind, &w.inputs).map_err(|e| e.to_string())?;
        // Random contiguous split: 2..8 shards at random cut points.
        let n_shards = g.usize_in(2, 8.min(units));
        let mut cuts: Vec<usize> = (0..n_shards - 1).map(|_| g.usize_in(1, units)).collect();
        cuts.push(0);
        cuts.push(units);
        cuts.sort_unstable();
        cuts.dedup();
        let parts: Vec<(usize, usize, Tensor)> = cuts
            .windows(2)
            .map(|p| -> Result<_, String> {
                let inp =
                    shard::shard_inputs(kind, &w.inputs, p[0], p[1]).map_err(|e| e.to_string())?;
                let out = reference_output(kind, &inp).map_err(|e| e.to_string())?;
                Ok((p[0], p[1], out))
            })
            .collect::<Result<_, _>>()?;
        let whole = shard::reassemble(kind, &w.inputs, &parts).map_err(|e| e.to_string())?;
        assert_prop(
            w.expected.allclose(&whole, 0.0),
            format!("{kind:?} x{} shards: reassembly differs", parts.len()),
        )
    });
}

#[test]
fn prop_mixed_sharded_and_unsharded_submits_keep_queue_invariants() {
    prop::check("mixed sharded + plain submits", 40, |g| {
        let (mut v, targets) = multi_target_vpe(g.u64_in(0, u64::MAX - 1));
        let kinds = [WorkloadKind::Matmul, WorkloadKind::Dotprod, WorkloadKind::Conv2d];
        let mut fns = Vec::new();
        for kind in kinds {
            fns.push(v.register_workload(kind).expect("register"));
        }
        // Random interleaving of plain submits, sharded submits, and
        // partial drains.
        let mut logical = 0u64;
        let mut records = Vec::new();
        for _ in 0..g.usize_in(5, 30) {
            match g.usize_in(0, 3) {
                0 => {
                    v.submit(*g.choose(&fns)).expect("submit");
                    logical += 1;
                }
                1 => {
                    let tickets = v.submit_sharded(*g.choose(&fns)).expect("submit_sharded");
                    assert_prop(!tickets.is_empty(), "sharded submit returned no tickets")?;
                    logical += 1;
                }
                _ => {
                    records.extend(v.drain().expect("drain"));
                }
            }
        }
        records.extend(v.drain().expect("drain"));

        // Exactly-once: one record per logical call, nothing in flight,
        // queue counters balanced, no staging leaks.
        assert_prop(
            records.len() as u64 == logical,
            format!("retired {} != submitted {logical}", records.len()),
        )?;
        assert_prop(v.in_flight() == 0, "queue must be empty after a full drain")?;
        assert_prop(
            v.dispatches_submitted() == v.dispatches_retired(),
            format!(
                "dispatch counters diverge: {} vs {}",
                v.dispatches_submitted(),
                v.dispatches_retired()
            ),
        )?;
        assert_prop(v.soc().shared.used_bytes() == 0, "staged params leaked")?;

        // Per-target serialization over the union of plain-call windows
        // and per-shard windows (aggregate records span several targets
        // and are replaced by their shards here).
        let mut windows: Vec<(TargetId, u64, u64)> = records
            .iter()
            .filter(|r| r.shards == 1)
            .map(|r| (r.target, r.start_ns, r.complete_ns))
            .collect();
        windows.extend(v.events().shard_windows());
        for &t in &targets {
            let mut on_t: Vec<_> = windows.iter().filter(|w| w.0 == t).collect();
            on_t.sort_by_key(|w| w.1);
            for p in on_t.windows(2) {
                assert_prop(
                    p[1].1 >= p[0].2,
                    format!("overlap on {t}: {:?} then {:?}", p[0], p[1]),
                )?;
            }
        }

        // Every aggregate record's makespan covers its shards.
        for r in records.iter().filter(|r| r.shards > 1) {
            assert_prop(
                r.complete_ns > r.start_ns,
                format!("degenerate aggregate window: {r:?}"),
            )?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Batched dispatch (same-target coalescing into one transport setup)
// ---------------------------------------------------------------------------

#[test]
fn prop_batched_mixed_traffic_keeps_invariants_and_saves_exact_setup() {
    prop::check("batched + sharded + plain submits", 40, |g| {
        // Queue bound 4 / batch cap 3: batches really form, the width
        // cap really bites, and traffic beyond the bound still bounces.
        let (mut v, targets) = multi_target_vpe_with(g.u64_in(0, u64::MAX - 1), 4, 3);
        let kinds = [WorkloadKind::Matmul, WorkloadKind::Dotprod, WorkloadKind::Conv2d];
        let mut fns = Vec::new();
        for kind in kinds {
            fns.push(v.register_workload(kind).expect("register"));
        }
        let mut logical = 0u64;
        let mut records = Vec::new();
        for _ in 0..g.usize_in(8, 40) {
            match g.usize_in(0, 4) {
                0 | 1 => {
                    v.submit(*g.choose(&fns)).expect("submit");
                    logical += 1;
                }
                2 => {
                    let tickets = v.submit_sharded(*g.choose(&fns)).expect("submit_sharded");
                    assert_prop(!tickets.is_empty(), "sharded submit returned no tickets")?;
                    logical += 1;
                }
                _ => {
                    records.extend(v.drain().expect("drain"));
                }
            }
        }
        records.extend(v.drain().expect("drain"));

        // Exactly-once retirement, balanced counters, no staging leaks.
        assert_prop(
            records.len() as u64 == logical,
            format!("retired {} != submitted {logical}", records.len()),
        )?;
        assert_prop(v.in_flight() == 0, "queue must be empty after a full drain")?;
        assert_prop(
            v.dispatches_submitted() == v.dispatches_retired(),
            "dispatch counters diverge",
        )?;
        assert_prop(v.soc().shared.used_bytes() == 0, "staged params leaked")?;

        // Every flushed batch saved exactly (width-1) x its target's
        // fixed transport setup, within the width cap; the queue's
        // cumulative counter agrees with the event log.
        let mut total_saved = 0u64;
        for (_, target, width, saved) in v.events().batches() {
            let setup =
                v.soc().target(target).expect("registered").transport.batch_setup_ns();
            assert_prop(
                (2..=3).contains(&width),
                format!("batch width {width} outside [2, cap]"),
            )?;
            assert_prop(
                saved == (width as u64 - 1) * setup,
                format!("batch on {target}: saved {saved} != ({width}-1) * {setup}"),
            )?;
            total_saved += saved;
        }
        assert_prop(
            v.saved_setup_ns() == total_saved,
            format!("saved counter {} != event sum {total_saved}", v.saved_setup_ns()),
        )?;

        // Per-target serialization over plain-call windows + per-shard
        // windows (batch members included — they are ordinary records).
        let mut windows: Vec<(TargetId, u64, u64)> = records
            .iter()
            .filter(|r| r.shards == 1)
            .map(|r| (r.target, r.start_ns, r.complete_ns))
            .collect();
        windows.extend(v.events().shard_windows());
        for &t in &targets {
            let mut on_t: Vec<_> = windows.iter().filter(|w| w.0 == t).collect();
            on_t.sort_by_key(|w| w.1);
            for p in on_t.windows(2) {
                assert_prop(
                    p[1].1 >= p[0].2,
                    format!("overlap on {t}: {:?} then {:?}", p[0], p[1]),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn half_full_batch_flushes_on_drain() {
    // Regression: a forming batch below the width cap must flush the
    // moment the caller drains — latency never waits on a batch that
    // will not fill.
    use vpe::coordinator::policy::AlwaysOffloadPolicy;
    use vpe::coordinator::{Vpe, VpeConfig};
    let mut cfg = VpeConfig::sim_only();
    cfg.max_batch_width = 4;
    cfg.max_queue_per_target = 4;
    let mut v = Vpe::with_policy(cfg, Box::new(AlwaysOffloadPolicy)).unwrap();
    let f = v.register_workload(WorkloadKind::Conv2d).unwrap();
    v.call(f).unwrap(); // offloads to the DSP
    v.submit(f).unwrap();
    v.submit(f).unwrap();
    assert_eq!(v.in_flight(), 2, "half-full batch is forming");
    let recs = v.drain().unwrap();
    assert_eq!(recs.len(), 2, "drain must flush the half-full batch");
    assert_eq!(v.in_flight(), 0);
    let batches = v.events().batches();
    assert_eq!(batches.len(), 1, "one coalesced flush expected");
    assert_eq!(batches[0].2, 2, "flushed at width 2, not the cap of 4");
}

// ---------------------------------------------------------------------------
// Per-target backend routing (mixed engines on one platform)
// ---------------------------------------------------------------------------

/// A coordinator whose platform mixes engines: the DM3730 pair on the
/// default engine, one explicit `BackendKind::Sim` unit, and one real
/// `BackendKind::Rayon` multicore unit (2 workers).  Cheap transports
/// so every unit sees traffic under always-offload.
fn mixed_engine_vpe(seed: u64, sim_only: bool) -> (vpe::coordinator::Vpe, TargetId, TargetId) {
    use vpe::coordinator::policy::AlwaysOffloadPolicy;
    use vpe::coordinator::VpeConfig;
    use vpe::platform::{BackendKind, TargetSpec, TransferModel, Transport};

    let mut cfg = if sim_only { VpeConfig::sim_only() } else { VpeConfig::default() };
    cfg.seed = seed;
    cfg.rayon_threads = 2;
    cfg.max_queue_per_target = 3;
    cfg.max_batch_width = 2;
    let mut v = vpe::coordinator::Vpe::with_policy(cfg, Box::new(AlwaysOffloadPolicy))
        .expect("vpe");
    let mut ids = Vec::new();
    // Rates far below the host's (and cheap transports) so these two
    // outrank the DSP's 100 ms setup and really see plain traffic.
    for (name, backend, speedup) in [
        ("sim-unit", BackendKind::Sim, 20.0),
        ("rayon-unit", BackendKind::Rayon, 30.0),
    ] {
        let id = v.soc_mut().add_target(
            TargetSpec::new(name, 1_000_000_000).with_backend(backend).with_transport(
                Transport::SharedMemory(TransferModel {
                    dispatch_fixed_ns: 2_000_000,
                    per_param_byte_ns: 1.0,
                }),
            ),
        );
        for kind in WorkloadKind::ALL {
            let host = v.soc().cost.rate_ns(kind, dm3730::ARM).expect("row");
            v.soc_mut().cost.set_rate(kind, id, host / speedup);
        }
        ids.push(id);
    }
    (v, ids[0], ids[1])
}

#[test]
fn prop_mixed_engine_traffic_keeps_queue_invariants() {
    prop::check("mixed sim+rayon submits", 20, |g| {
        let (mut v, sim_unit, rayon_unit) = mixed_engine_vpe(g.u64_in(0, u64::MAX - 1), true);
        // Cheap kinds only: the rayon unit really computes its calls.
        let kinds = [WorkloadKind::Dotprod, WorkloadKind::Conv2d];
        let mut fns = Vec::new();
        for kind in kinds {
            fns.push(v.register_workload(kind).expect("register"));
        }
        let mut logical = 0u64;
        let mut records = Vec::new();
        for _ in 0..g.usize_in(5, 15) {
            match g.usize_in(0, 3) {
                0 => {
                    v.submit(*g.choose(&fns)).expect("submit");
                    logical += 1;
                }
                1 => {
                    let t = v.submit_sharded(*g.choose(&fns)).expect("submit_sharded");
                    assert_prop(!t.is_empty(), "sharded submit returned no tickets")?;
                    logical += 1;
                }
                _ => records.extend(v.drain().expect("drain")),
            }
        }
        records.extend(v.drain().expect("drain"));

        // Exactly-once retirement across both engine kinds.
        assert_prop(
            records.len() as u64 == logical,
            format!("retired {} != submitted {logical}", records.len()),
        )?;
        assert_prop(v.in_flight() == 0, "queue must be empty after a full drain")?;
        assert_prop(
            v.dispatches_submitted() == v.dispatches_retired(),
            "dispatch counters diverge",
        )?;
        assert_prop(v.soc().shared.used_bytes() == 0, "staged params leaked")?;

        // Batches are homogeneous per engine *by construction* (they
        // form per target, and each target binds exactly one engine):
        // every flushed batch names one target, and that target resolves
        // to exactly one engine.
        for (_, target, width, _) in v.events().batches() {
            assert_prop(width == 2, format!("width {width} beyond the cap of 2"))?;
            let engine = v.backend_name_on(target);
            assert_prop(
                ["sim", "rayon", "reference"].contains(&engine),
                format!("batch target {target} has no engine"),
            )?;
        }

        // Per-target serialization holds across engines (plain windows
        // union per-shard windows).
        let mut windows: Vec<(TargetId, u64, u64)> = records
            .iter()
            .filter(|r| r.shards == 1)
            .map(|r| (r.target, r.start_ns, r.complete_ns))
            .collect();
        windows.extend(v.events().shard_windows());
        for t in [dm3730::ARM, dm3730::DSP, sim_unit, rayon_unit] {
            let mut on_t: Vec<_> = windows.iter().filter(|w| w.0 == t).collect();
            on_t.sort_by_key(|w| w.1);
            for p in on_t.windows(2) {
                assert_prop(
                    p[1].1 >= p[0].2,
                    format!("overlap on {t}: {:?} then {:?}", p[0], p[1]),
                )?;
            }
        }

        // Explicitly sim-backed dispatches never produce numerics; the
        // rayon unit always does (it computes for real even sim-only).
        for r in records.iter().filter(|r| r.shards == 1) {
            if r.target == sim_unit {
                assert_prop(r.wall.is_none(), format!("sim unit produced a wall: {r:?}"))?;
            }
            if r.target == rayon_unit {
                assert_prop(r.wall.is_some(), format!("rayon unit skipped compute: {r:?}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rayon_shard_reassembly_is_bit_exact() {
    use vpe::workloads::shard;
    let kinds: Vec<WorkloadKind> = WorkloadKind::ALL
        .into_iter()
        .filter(|k| shard::shardable(*k) && *k != WorkloadKind::Matmul)
        .collect();
    prop::check("sharded across sim+rayon == reference", 12, |g| {
        // Real numerics everywhere: a fan-out mixing a simulated unit
        // and a real multicore unit must reassemble bit-exact against
        // the registered instance's reference output.
        let (mut v, _, rayon_unit) = mixed_engine_vpe(g.u64_in(0, u64::MAX - 1), false);
        let kind = *g.choose(&kinds);
        let f = v.register_workload(kind).expect("register");
        let rec = v.call_sharded(f).expect("call_sharded");
        assert_prop(
            rec.output_ok != Some(false),
            format!("{kind:?}: mixed-engine reassembly differs from the reference"),
        )?;
        if rec.shards >= 2 {
            let on: std::collections::HashSet<TargetId> =
                v.events().shard_windows().iter().map(|w| w.0).collect();
            // The planner is free to drop units, but when the rayon
            // unit participates its shards must have really computed.
            if on.contains(&rayon_unit) {
                assert_prop(
                    rec.output_ok == Some(true),
                    format!("{kind:?}: rayon shard broke the group: {rec:?}"),
                )?;
            }
        }
        assert_prop(v.in_flight() == 0, "queue must drain")?;
        assert_prop(v.soc().shared.used_bytes() == 0, "staged params leaked")
    });
}

// ---------------------------------------------------------------------------
// Workload references (cross-validated against each other)
// ---------------------------------------------------------------------------

#[test]
fn prop_blocked_matmul_matches_naive() {
    prop::check("blocked == naive matmul", 40, |g| {
        let n = g.usize_in(1, 40);
        let a = g.vec_i32(n * n, -8, 8);
        let b = g.vec_i32(n * n, -8, 8);
        let block = g.usize_in(1, 24);
        let want = vpe::workloads::matmul::reference(&a, &b, n);
        let got = vpe::workloads::matmul::reference_blocked(&a, &b, n, block);
        assert_prop(got == want, format!("n={n} block={block}"))
    });
}

#[test]
fn prop_complement_involution_and_alphabet() {
    prop::check("complement involution", 100, |g| {
        let n = g.usize_in(1, 4096);
        let seq: Vec<i32> = (0..n).map(|_| g.i64_in(0, 4) as i32).collect();
        let c = vpe::workloads::complement::reference(&seq);
        assert_prop(c.iter().all(|&x| (0..4).contains(&x)), "out of alphabet")?;
        let cc = vpe::workloads::complement::reference(&c);
        assert_prop(cc == seq, "not an involution")
    });
}

#[test]
fn prop_pattern_count_matches_bruteforce_windows() {
    prop::check("pattern count", 100, |g| {
        let n = g.usize_in(4, 512);
        let p = g.usize_in(1, 8.min(n));
        let seq: Vec<i32> = (0..n).map(|_| g.i64_in(0, 3) as i32).collect();
        let pat: Vec<i32> = (0..p).map(|_| g.i64_in(0, 3) as i32).collect();
        let got = vpe::workloads::pattern::reference(&seq, &pat);
        let brute = (0..=n - p).filter(|&s| seq[s..s + p] == pat[..]).count() as i32;
        assert_prop(got == brute, format!("n={n} p={p}: {got} != {brute}"))
    });
}

#[test]
fn prop_fft_parseval_and_linearity() {
    prop::check("fft parseval", 40, |g| {
        let n = 1usize << g.usize_in(1, 10);
        let re: Vec<f32> = (0..n).map(|_| (g.f64_unit() * 2.0 - 1.0) as f32).collect();
        let im: Vec<f32> = (0..n).map(|_| (g.f64_unit() * 2.0 - 1.0) as f32).collect();
        let (fr, fi) = vpe::workloads::fft::reference(&re, &im);
        let t: f64 = re.iter().zip(&im).map(|(a, b)| (a * a + b * b) as f64).sum();
        let f: f64 =
            fr.iter().zip(&fi).map(|(a, b)| (a * a + b * b) as f64).sum::<f64>() / n as f64;
        assert_prop((t - f).abs() <= 1e-4 * t.max(1.0), format!("n={n}: {t} vs {f}"))
    });
}

// ---------------------------------------------------------------------------
// Trace v3: lossless persistence + degraded-fidelity loading
// ---------------------------------------------------------------------------

/// A randomized v3 trace exercising every optional field: meta header,
/// per-entry candidate slices, epochs, coalesced flags, shard counts
/// and counterfactual plans.
fn random_v4_trace(g: &mut vpe::util::prop::Gen) -> vpe::coordinator::trace::Trace {
    use vpe::coordinator::trace::{
        RecordedCandidate, RecordedPlan, RecordedShard, Trace, TraceEntry,
    };
    let mut t = Trace::default();
    t.meta.max_batch_width = g.usize_in(1, 8);
    t.meta.min_samples = g.u64_in(1, 10);
    // Exact dyadic fraction: bit-exact through the shortest-roundtrip
    // float formatting either way, but keep the input unambiguous.
    t.meta.share_threshold = g.u64_in(0, 64) as f64 / 64.0;
    let units = g.usize_in(1, 5);
    t.meta.setups = (0..units)
        .map(|s| (TargetId(s as u16), if s == 0 { 0 } else { g.u64_in(0, 1 << 40) }))
        .collect();
    t.meta.power = (0..units)
        .map(|s| (TargetId(s as u16), g.u64_in(1, 64), g.u64_in(0, 8)))
        .collect();
    for i in 0..g.usize_in(1, 25) {
        let prices: Vec<(TargetId, u64)> =
            (0..units).map(|s| (TargetId(s as u16), g.u64_in(1, 1 << 50))).collect();
        let candidates: Vec<RecordedCandidate> = (1..units)
            .map(|s| RecordedCandidate {
                target: TargetId(s as u16),
                predicted_ns: g.u64_in(1, 1 << 50),
                amortized_ns: g.u64_in(1, 1 << 50),
                predicted_energy_nj: g.u64_in(1, 1 << 55),
                amortized_energy_nj: g.u64_in(1, 1 << 55),
            })
            .collect();
        let host = g.bool().then(|| RecordedCandidate {
            target: TargetId(0),
            predicted_ns: g.u64_in(1, 1 << 50),
            amortized_ns: g.u64_in(1, 1 << 50),
            predicted_energy_nj: g.u64_in(1, 1 << 55),
            amortized_energy_nj: g.u64_in(1, 1 << 55),
        });
        let plan = g.bool().then(|| RecordedPlan {
            units: g.usize_in(2, 2000),
            items_per_unit: g.u64_in(1, 1 << 40) as f64 / 16.0,
            makespan_ns: g.u64_in(1, 1 << 50),
            shards: (0..g.usize_in(2, 4))
                .map(|s| RecordedShard {
                    target: TargetId(s as u16),
                    units: g.usize_in(1, 1000),
                    fixed_ns: g.u64_in(0, 1 << 40),
                    predicted_ns: g.u64_in(1, 1 << 50),
                })
                .collect(),
        });
        t.entries.push(TraceEntry {
            function: g.u64_in(0, 3) as u32,
            kind: *g.choose(&WorkloadKind::ALL),
            executed_on: TargetId(g.usize_in(0, units) as u16),
            exec_ns: g.u64_in(1, 1 << 50),
            energy_nj: g.u64_in(1, 1 << 55),
            profiling_ns: g.u64_in(0, 1 << 30),
            cycles: g.u64_in(0, 1 << 50),
            issue_epoch: g.u64_in(0, i as u64 + 1),
            retire_epoch: g.u64_in(i as u64, i as u64 + 10),
            coalesced: g.bool(),
            fanned: g.bool(),
            shards: g.usize_in(1, 5),
            prices,
            candidates,
            host,
            plan,
        });
    }
    t
}

#[test]
fn prop_trace_v4_roundtrips_bit_exact() {
    prop::check("trace v4 json roundtrip", 120, |g| {
        let t = random_v4_trace(g);
        let json = t.to_json();
        let back =
            vpe::coordinator::trace::Trace::from_json(&json).map_err(|e| e.to_string())?;
        assert_prop(!back.degraded(), "a v4 document must not load degraded")?;
        assert_prop(!back.degraded_energy(), "a v4 document carries real joules")?;
        assert_prop(t == back, "amortized/shard/energy fields must round-trip bit-exact")?;
        // And re-serializing is a fixed point.
        assert_prop(back.to_json() == json, "serialization must be stable")
    });
}

#[test]
fn v2_documents_load_with_the_degraded_flag_not_a_parse_error() {
    let doc = r#"{"format":"vpe-trace-v2","entries":[
{"f":0,"kind":"matmul","on":1,"exec_ns":100,"prof_ns":5,"prices":[[0,100],[1,50]]},
{"f":0,"kind":"matmul","on":0,"exec_ns":101,"prof_ns":5,"prices":[[0,101],[1,50]]}]}"#;
    let t = vpe::coordinator::trace::Trace::from_json(doc).expect("v2 must still load");
    assert!(t.degraded(), "pre-v3 fidelity must be flagged");
    assert_eq!(t.entries.len(), 2);
    assert!(t.entries[0].candidates.is_empty());
    assert!(t.entries[0].plan.is_none());
    let out = vpe::coordinator::trace::replay(
        &t,
        &mut vpe::coordinator::policy::NeverOffloadPolicy,
    );
    assert!(out.degraded_fidelity, "replay must surface the degraded fidelity");
}

#[test]
fn prop_energy_is_conserved_per_target() {
    use vpe::platform::PowerModel;

    prop::check("energy conservation", 40, |g| {
        let (mut v, targets) = multi_target_vpe_with(g.u64_in(0, u64::MAX - 1), 2, 8);
        // Distinct integer power models per unit, so a bookkeeping slip
        // on any one target breaks the sums.
        for (i, &t) in targets.iter().enumerate() {
            let active = g.u64_in(1, 16) + i as u64;
            let idle = g.u64_in(0, 3);
            v.soc_mut().registry.get_mut(t).expect("registered").power =
                PowerModel::new(active, idle);
        }
        let kinds = [WorkloadKind::Matmul, WorkloadKind::Dotprod, WorkloadKind::Conv2d];
        let mut fns = Vec::new();
        for kind in kinds {
            fns.push(v.register_workload(kind).expect("register"));
        }
        let mut records = Vec::new();
        for _ in 0..g.usize_in(5, 40) {
            if g.bool() {
                let f = *g.choose(&fns);
                v.submit(f).expect("submit");
            } else {
                records.extend(v.drain().expect("drain"));
            }
        }
        records.extend(v.drain().expect("drain"));

        // (1) Conservation: per target, the charged joules are exactly
        // its effective active watts times its cumulative busy time.
        let mut total_charged = 0u64;
        for &t in &targets {
            let busy = v.scheduler().occupied_ns(t);
            let watts = v.soc().active_watts(t);
            let charged = v.charged_energy_nj(t);
            assert_prop(
                charged == busy * watts,
                format!("{t}: charged {charged} nJ != {watts} W x {busy} ns"),
            )?;
            total_charged += charged;
        }
        // (2) Ledger: per-record charges sum to the per-target ledger.
        let from_records: u64 = records.iter().map(|r| r.energy_nj).sum();
        assert_prop(
            from_records == total_charged,
            format!("records sum {from_records} != target ledger {total_charged}"),
        )?;
        // (3) Idle integration: the platform total is the charged
        // active energy plus every unit's idle-watts gap integral.
        let idle: u64 = targets.iter().map(|&t| v.idle_energy_nj(t)).sum();
        assert_prop(
            v.total_energy_nj() == total_charged + idle,
            format!(
                "total {} != active {total_charged} + idle {idle}",
                v.total_energy_nj()
            ),
        )
    });
}

#[test]
fn prop_same_policy_replay_reproduces_recorded_joules_exactly() {
    use vpe::coordinator::policy::BlindOffloadPolicy;
    use vpe::coordinator::VpeConfig;
    use vpe::platform::PowerModel;

    prop::check("v4 replay joule reproduction", 25, |g| {
        let mut cfg = VpeConfig::sim_only();
        cfg.seed = g.u64_in(0, u64::MAX - 1);
        let mut v = vpe::coordinator::Vpe::new(cfg).expect("vpe");
        // Asymmetric powers: the host frugal, the DSP hungry — recorded
        // joules are far from the 1 W time-equivalence.
        v.soc_mut().registry.get_mut(dm3730::ARM).expect("arm").power =
            PowerModel::new(g.u64_in(1, 4), 0);
        v.soc_mut().registry.get_mut(dm3730::DSP).expect("dsp").power =
            PowerModel::new(g.u64_in(2, 9), 1);
        v.enable_tracing();
        let f = v.register_workload(*g.choose(&WorkloadKind::ALL)).expect("register");
        v.run(f, g.usize_in(8, 25)).expect("run");
        let trace = v.trace().expect("tracing enabled").clone();
        assert_prop(!trace.degraded_energy(), "fresh traces carry joules")?;
        let out =
            vpe::coordinator::trace::replay(&trace, &mut BlindOffloadPolicy::default());
        assert_prop(out.diverged() == 0, out.divergence_report())?;
        assert_prop(
            out.total_ns == trace.total_ns(),
            format!("replayed ns {} != recorded {}", out.total_ns, trace.total_ns()),
        )?;
        assert_prop(
            out.total_energy_nj == trace.total_energy_nj(),
            format!(
                "replayed nJ {} != recorded {}",
                out.total_energy_nj,
                trace.total_energy_nj()
            ),
        )
    });
}

// ---------------------------------------------------------------------------
// Serving front-end (admission, completion handles, DRR fairness)
// ---------------------------------------------------------------------------

/// A serving core over one strictly-fastest unit: every function pins
/// to it, so all tenants contend for the same bottleneck and the
/// fairness property is about the scheduler, not about load placement.
fn serving_server(
    seed: u64,
    max_inflight_total: usize,
    tenant_quota: usize,
) -> (vpe::coordinator::SchedulerCore, Vec<FunctionId>) {
    use vpe::coordinator::policy::AlwaysOffloadPolicy;
    use vpe::coordinator::{SchedulerCore, VpeConfig};
    use vpe::platform::{TargetSpec, TransferModel, Transport};
    use vpe::workloads::PaperScale;

    let mut cfg = VpeConfig::sim_only();
    cfg.seed = seed;
    cfg.max_inflight_total = max_inflight_total;
    cfg.tenant_quota = tenant_quota;
    let mut v = vpe::coordinator::Vpe::with_policy(cfg, Box::new(AlwaysOffloadPolicy))
        .expect("vpe");
    let fast = v.soc_mut().add_target(
        TargetSpec::new("fast", 1_000_000_000).with_transport(Transport::SharedMemory(
            TransferModel { dispatch_fixed_ns: 500_000, per_param_byte_ns: 1.0 },
        )),
    );
    let pool = [
        (WorkloadKind::Dotprod, 5e5),
        (WorkloadKind::Pattern, 1e6),
        (WorkloadKind::Conv2d, 2e6),
    ];
    for (kind, _) in pool {
        v.soc_mut().cost.set_rate(kind, fast, 1.0);
    }
    let mut fns = Vec::new();
    for (kind, items) in pool {
        let f = v.register_workload(kind).expect("register");
        v.set_scale(f, PaperScale { items, param_bytes: 48, payload_bytes: 4096 })
            .expect("scale");
        // Warm-up: the first call profiles on the host and commits the
        // offload, so serving-path predictions are steady-state.
        v.call(f).expect("warm-up");
        assert_eq!(v.current_target(f).expect("target"), fast, "must pin to the fast unit");
        fns.push(f);
    }
    (SchedulerCore::new(v), fns)
}

#[test]
fn prop_serving_admitted_calls_complete_exactly_once() {
    use vpe::coordinator::serving::{AdmitOutcome, Completion, TenantId};

    prop::check("serving exactly-once completion", 25, |g| {
        let tenants = g.usize_in(2, 7) as u32;
        let (mut server, fns) = serving_server(g.u64_in(0, u64::MAX - 1), 10_000, 10_000);
        let mut handles: Vec<(u32, Completion)> = Vec::new();
        let mut admitted = vec![0u64; tenants as usize];
        for _ in 0..g.usize_in(10, 60) {
            let t = g.u64_in(0, tenants as u64) as u32;
            let f = *g.choose(&fns);
            match server.try_submit(TenantId(t), f).map_err(|e| e.to_string())? {
                AdmitOutcome::Admitted(c) => {
                    handles.push((t, c));
                    admitted[t as usize] += 1;
                }
                AdmitOutcome::Rejected { .. } => {
                    return Err("bounds are far above the storm; nothing may reject".into())
                }
            }
            // Occasionally drive the server mid-storm: completions may
            // resolve before the final drain.
            if g.bool() {
                server.pump().map_err(|e| e.to_string())?;
            }
        }
        server.run_until_idle().map_err(|e| e.to_string())?;

        for (t, c) in &handles {
            let rec = c.poll();
            assert_prop(c.is_done() && rec.is_some(), "handle left unresolved")?;
            assert_prop(
                rec.expect("checked").tenant == Some(TenantId(*t)),
                "record resolved under the wrong tenant",
            )?;
        }
        for s in server.vpe().serving_stats() {
            let t = s.tenant.0 as usize;
            assert_prop(
                s.submitted == admitted[t] && s.completed == admitted[t] && s.rejected == 0,
                format!("stats drifted for tenant {t}: {s:?}"),
            )?;
        }
        assert_prop(server.accepted_inflight() == 0, "accepted population must drain to 0")?;
        assert_prop(server.vpe().in_flight() == 0, "dispatch queue must drain")?;
        assert_prop(server.vpe().soc().shared.used_bytes() == 0, "staged params leaked")
    });
}

#[test]
fn prop_admission_never_exceeds_the_inflight_bound() {
    use vpe::coordinator::serving::{AdmitOutcome, TenantId};
    use vpe::coordinator::RejectReason;

    prop::check("admission bound", 25, |g| {
        let bound = g.usize_in(2, 13);
        // Quotas sit far above the server-wide bound: every rejection
        // in this property must be ServerSaturated.
        let (mut server, fns) = serving_server(g.u64_in(0, u64::MAX - 1), bound, bound * 8);
        let mut rejected = 0u64;
        for i in 0..g.usize_in(2, 5) * bound + bound + 1 {
            let t = g.u64_in(0, 3) as u32;
            let f = *g.choose(&fns);
            match server.try_submit(TenantId(t), f).map_err(|e| e.to_string())? {
                AdmitOutcome::Admitted(_) => {}
                AdmitOutcome::Rejected { reason, retry_after_ns } => {
                    assert_prop(
                        reason == RejectReason::ServerSaturated,
                        format!("unexpected reason {reason:?}"),
                    )?;
                    assert_prop(retry_after_ns > 0, "retry hint must be positive")?;
                    rejected += 1;
                }
            }
            assert_prop(
                server.accepted_inflight() <= bound,
                format!("{} accepted > bound {bound}", server.accepted_inflight()),
            )?;
            // Drain only after the storm has provably overrun the
            // bound once; then keep the interleaving random.
            if i > bound && g.bool() {
                server.pump().map_err(|e| e.to_string())?;
                assert_prop(server.accepted_inflight() <= bound, "bound broken by pump")?;
            }
        }
        assert_prop(rejected > 0, "storm exceeded the bound yet nothing was rejected")?;
        server.run_until_idle().map_err(|e| e.to_string())?;
        assert_prop(server.accepted_inflight() == 0, "must drain")?;
        // The drained server admits again.
        let f = *g.choose(&fns);
        assert_prop(
            matches!(
                server.try_submit(TenantId(0), f).map_err(|e| e.to_string())?,
                AdmitOutcome::Admitted(_)
            ),
            "drained server must re-admit",
        )?;
        server.run_until_idle().map_err(|e| e.to_string())?;
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Fault injection & mid-flight recovery
// ---------------------------------------------------------------------------

#[test]
fn prop_fault_storms_retire_every_call_exactly_once() {
    use vpe::coordinator::CallOutcome;
    use vpe::sim::FaultInjector;

    prop::check("random fault storm invariants", 30, |g| {
        // Queue bound 4 / batch cap 3 so batches really form — a fault
        // mid-storm salvages forming members, not just in-flight work.
        let (mut v, targets) = multi_target_vpe_with(g.u64_in(0, u64::MAX - 1), 4, 3);
        v.set_fault_injector(
            FaultInjector::new(g.u64_in(0, u64::MAX - 1)).with_flaky(0.05),
        );
        let kinds = [WorkloadKind::Matmul, WorkloadKind::Dotprod, WorkloadKind::Conv2d];
        let mut fns = Vec::new();
        for kind in kinds {
            fns.push(v.register_workload(kind).expect("register"));
        }
        // Only remote units fail or throttle — the host cannot.
        let remotes: Vec<TargetId> =
            targets.iter().copied().filter(|t| !t.is_host()).collect();
        let mut down: Vec<TargetId> = Vec::new();
        let mut logical = 0u64;
        let mut records = Vec::new();
        for _ in 0..g.usize_in(10, 50) {
            match g.usize_in(0, 8) {
                0 => {
                    // Kill a live unit mid-flight: staged and in-flight
                    // work on it must be salvaged onto survivors.
                    let up: Vec<TargetId> =
                        remotes.iter().copied().filter(|t| !down.contains(t)).collect();
                    if !up.is_empty() {
                        let t = *g.choose(&up);
                        v.fail_target(t).map_err(|e| e.to_string())?;
                        down.push(t);
                    }
                }
                1 => {
                    // Heal: the unit rejoins the candidate set.
                    if !down.is_empty() {
                        let t = down.swap_remove(g.usize_in(0, down.len()));
                        v.heal_target(t);
                    }
                }
                2 => {
                    // Thermal throttle: forming work on it is repriced.
                    let up: Vec<TargetId> =
                        remotes.iter().copied().filter(|t| !down.contains(t)).collect();
                    if !up.is_empty() {
                        let t = *g.choose(&up);
                        let factor = 1.5 + g.f64_unit() * 2.0;
                        v.degrade_target(t, factor).map_err(|e| e.to_string())?;
                    }
                }
                3 | 4 => {
                    let tickets = v.submit_sharded(*g.choose(&fns)).expect("submit_sharded");
                    assert_prop(!tickets.is_empty(), "sharded submit returned no tickets")?;
                    logical += 1;
                }
                5 => records.extend(v.drain().expect("drain")),
                _ => {
                    v.submit(*g.choose(&fns)).expect("submit");
                    logical += 1;
                }
            }
        }
        records.extend(v.drain().expect("drain"));

        // Exactly-once resolution: one record per admitted call — Ok or
        // a typed failure, never silence and never a duplicate.
        assert_prop(
            records.len() as u64 == logical,
            format!("resolved {} != submitted {logical}", records.len()),
        )?;
        assert_prop(v.in_flight() == 0, "queue must be empty after a full drain")?;
        assert_prop(
            v.dispatches_submitted() == v.dispatches_retired(),
            format!(
                "dispatch counters diverge: {} vs {}",
                v.dispatches_submitted(),
                v.dispatches_retired()
            ),
        )?;
        assert_prop(v.soc().shared.used_bytes() == 0, "staged params leaked")?;

        // Typed failures are zero-cost: a call that never ran anywhere
        // must not carry an execution window or an energy charge.
        for r in &records {
            if matches!(r.outcome, CallOutcome::Failed(_)) {
                assert_prop(
                    r.exec_ns == 0 && r.energy_nj == 0,
                    format!("failed record carries cost: {r:?}"),
                )?;
            }
        }

        // Energy conservation for the time each unit was actually
        // alive: salvage refunds the un-run tail and `interrupt` clamps
        // the busy horizon, so the charged-joule ledger still equals
        // watts x occupied time on every target — through any storm.
        for &t in &targets {
            let busy = v.scheduler().occupied_ns(t);
            let watts = v.soc().active_watts(t);
            let charged = v.charged_energy_nj(t);
            assert_prop(
                charged == busy * watts,
                format!("{t}: charged {charged} nJ != {watts} W x {busy} ns"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_multi_tenant_fault_storms_resolve_every_admitted_call() {
    use vpe::coordinator::serving::{AdmitOutcome, Completion, TenantId};
    use vpe::sim::FaultInjector;

    prop::check("serving exactly-once under faults", 15, |g| {
        let tenants = g.usize_in(2, 6) as u32;
        let (mut server, fns) = serving_server(g.u64_in(0, u64::MAX - 1), 10_000, 10_000);
        server.vpe_mut().set_fault_injector(
            FaultInjector::new(g.u64_in(0, u64::MAX - 1)).with_flaky(0.05),
        );
        // The bottleneck unit every function pins to (see the helper).
        let fast = server.vpe().current_target(fns[0]).expect("pinned");
        let mut alive = true;
        let mut handles: Vec<(u32, Completion)> = Vec::new();
        for _ in 0..g.usize_in(15, 50) {
            match g.usize_in(0, 8) {
                0 if alive => {
                    server.vpe_mut().fail_target(fast).map_err(|e| e.to_string())?;
                    alive = false;
                }
                1 if !alive => {
                    server.vpe_mut().heal_target(fast);
                    alive = true;
                }
                _ => {
                    let t = g.u64_in(0, tenants as u64) as u32;
                    let f = *g.choose(&fns);
                    match server.try_submit(TenantId(t), f).map_err(|e| e.to_string())? {
                        AdmitOutcome::Admitted(c) => handles.push((t, c)),
                        AdmitOutcome::Rejected { .. } => {
                            return Err(
                                "bounds are far above the storm; nothing may reject".into()
                            )
                        }
                    }
                }
            }
            if g.bool() {
                server.pump().map_err(|e| e.to_string())?;
            }
        }
        server.run_until_idle().map_err(|e| e.to_string())?;

        // Every admitted handle resolved exactly once, under its tenant.
        for (t, c) in &handles {
            let rec = c.poll();
            assert_prop(c.is_done() && rec.is_some(), "handle left unresolved")?;
            assert_prop(
                rec.expect("checked").tenant == Some(TenantId(*t)),
                "record resolved under the wrong tenant",
            )?;
        }
        // The books close: submitted splits exactly into completed-Ok
        // plus typed failures; nothing rejected, nothing stranded.
        for s in server.vpe().serving_stats() {
            assert_prop(
                s.submitted == s.completed + s.failed && s.rejected == 0,
                format!("stats drifted: {s:?}"),
            )?;
        }
        assert_prop(server.accepted_inflight() == 0, "accepted population must drain to 0")?;
        assert_prop(server.vpe().in_flight() == 0, "dispatch queue must drain")?;
        assert_prop(server.vpe().soc().shared.used_bytes() == 0, "staged params leaked")?;

        // Conservation holds through the storm on every unit.
        let v = server.vpe();
        for t in [dm3730::ARM, dm3730::DSP, fast] {
            let busy = v.scheduler().occupied_ns(t);
            let watts = v.soc().active_watts(t);
            assert_prop(
                v.charged_energy_nj(t) == busy * watts,
                format!("{t}: charged {} nJ != {watts} W x {busy} ns",
                    v.charged_energy_nj(t)),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_threaded_ingest_storm_preserves_every_serving_invariant() {
    use vpe::coordinator::serving::{AdmitOutcome, Completion, Ingress, TenantId};
    use vpe::sim::FaultInjector;

    // Eight real OS threads submit through lock-free `Ingress` clones
    // while a dedicated pump thread drains, under a flaky-dispatch
    // fault storm.  The threaded path promises no fixed interleaving —
    // only exactly-once resolution, a never-exceeded admission bound,
    // balanced books, and joule conservation.  That is what's checked.
    prop::check("threaded ingest under fault storm", 8, |g| {
        const THREADS: usize = 8;
        let per_thread = g.usize_in(24, 64);
        let quota = g.usize_in(4, 12);
        let max_total = quota * THREADS;
        let (mut server, fns) = serving_server(g.u64_in(0, u64::MAX - 1), max_total, quota);
        server.vpe_mut().set_fault_injector(
            FaultInjector::new(g.u64_in(0, u64::MAX - 1)).with_flaky(0.05),
        );
        let seeds: Vec<u64> = (0..THREADS).map(|_| g.u64_in(0, u64::MAX - 1)).collect();
        let ingresses: Vec<Ingress> =
            (0..THREADS).map(|t| server.ingress(TenantId(t as u32))).collect();
        let pump = server.spawn_pump();

        let workers: Vec<_> = ingresses
            .into_iter()
            .zip(seeds)
            .map(|(ing, seed)| {
                let fns = fns.clone();
                std::thread::spawn(move || {
                    let mut rng = seed;
                    let mut handles = Vec::with_capacity(per_thread);
                    let mut spins = 0u64;
                    while handles.len() < per_thread {
                        rng = rng
                            .wrapping_mul(6364136223846793005)
                            .wrapping_add(1442695040888963407);
                        let f = fns[((rng >> 33) as usize) % fns.len()];
                        match ing.try_submit(f).expect("bound function never errors") {
                            AdmitOutcome::Admitted(c) => handles.push(c),
                            AdmitOutcome::Rejected { retry_after_ns, .. } => {
                                assert!(retry_after_ns > 0, "retry hint must be positive");
                                spins += 1;
                                assert!(spins < 50_000_000, "ingest thread starved");
                                std::thread::yield_now();
                            }
                        }
                    }
                    handles
                })
            })
            .collect();

        // Sample the admission bound from outside while the storm runs:
        // CAS reservations must make over-admission impossible at every
        // instant, not just at the end.
        let mut handles: Vec<Completion> = Vec::new();
        let mut bound_breaches = 0usize;
        let mut live = workers;
        while !live.is_empty() {
            if pump.accepted_inflight() > max_total {
                bound_breaches += 1;
            }
            let (done, rest): (Vec<_>, Vec<_>) =
                live.into_iter().partition(|w| w.is_finished());
            for w in done {
                handles.extend(w.join().expect("ingest worker panicked"));
            }
            live = rest;
            std::thread::yield_now();
        }
        let swept = pump.invariant_violations();
        let server = pump.shutdown().map_err(|e| e.to_string())?;

        assert_prop(bound_breaches == 0, "accepted population exceeded max_inflight_total")?;
        assert_prop(swept == 0, "pump sweeps saw a core-invariant violation")?;
        assert_prop(
            handles.len() == THREADS * per_thread,
            format!("admitted {} != {}", handles.len(), THREADS * per_thread),
        )?;
        for c in &handles {
            assert_prop(c.is_done(), "handle left unresolved after shutdown")?;
        }
        assert_prop(server.is_idle(), "shutdown left the books non-empty")?;
        assert_prop(server.accepted_inflight() == 0, "accepted population must drain to 0")?;
        assert_prop(server.vpe().in_flight() == 0, "dispatch queue must drain")?;
        assert_prop(server.vpe().soc().shared.used_bytes() == 0, "staged params leaked")?;
        for s in server.vpe().serving_stats() {
            assert_prop(
                s.submitted == per_thread as u64,
                format!("tenant {} submitted {} != {per_thread}", s.tenant.0, s.submitted),
            )?;
            assert_prop(
                s.submitted == s.completed + s.failed,
                format!("books unbalanced for tenant {}: {s:?}", s.tenant.0),
            )?;
        }
        // Joule conservation on every unit, through the whole storm.
        let v = server.vpe();
        let fast = v.current_target(fns[0]).expect("pinned");
        for t in [dm3730::ARM, dm3730::DSP, fast] {
            let busy = v.scheduler().occupied_ns(t);
            let watts = v.soc().active_watts(t);
            assert_prop(
                v.charged_energy_nj(t) == busy * watts,
                format!("{t}: charged {} nJ != {watts} W x {busy} ns", v.charged_energy_nj(t)),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_drr_fair_share_lower_bound() {
    use vpe::coordinator::serving::{AdmitOutcome, TenantId};

    prop::check("DRR fair-share lower bound", 10, |g| {
        let tenants = g.usize_in(3, 7);
        let quota = 16usize;
        let (mut server, fns) = serving_server(g.u64_in(0, u64::MAX - 1), 10_000, quota);
        let mut admitted = vec![0usize; tenants];
        let mut completed = vec![0usize; tenants];
        for _ in 0..g.usize_in(25, 40) {
            // Keep every tenant topped up to its quota: all of them
            // stay continuously backlogged.
            for t in 0..tenants {
                while admitted[t] - completed[t] < quota {
                    let f = *g.choose(&fns);
                    match server.try_submit(TenantId(t as u32), f).map_err(|e| e.to_string())? {
                        AdmitOutcome::Admitted(_) => admitted[t] += 1,
                        AdmitOutcome::Rejected { .. } => {
                            return Err("refill to quota must not reject".into())
                        }
                    }
                }
            }
            for _ in 0..8 {
                match server.pump().map_err(|e| e.to_string())? {
                    Some(rec) => {
                        if let Some(TenantId(t)) = rec.tenant {
                            completed[t as usize] += 1;
                        }
                    }
                    None => break,
                }
            }
        }
        // Every tenant is still backlogged, so DRR owes each an equal
        // share of released cost — within one call of granularity.
        for t in 0..tenants {
            assert_prop(
                server.queued_for(TenantId(t as u32)) > 0,
                format!("tenant {t} ran dry; the share bound would be vacuous"),
            )?;
        }
        let served: Vec<u64> =
            (0..tenants).map(|t| server.served_ns(TenantId(t as u32))).collect();
        let mean = served.iter().sum::<u64>() as f64 / tenants as f64;
        let min = *served.iter().min().expect("nonempty") as f64;
        assert_prop(
            min >= 0.5 * mean,
            format!("fair share violated: min {min} < half of mean {mean} ({served:?})"),
        )?;
        server.run_until_idle().map_err(|e| e.to_string())?;
        assert_prop(server.vpe().in_flight() == 0, "must drain")
    });
}

// ---------------------------------------------------------------------------
// Scenario gauntlet (the full serving path, end to end, per random cell)
// ---------------------------------------------------------------------------

#[test]
fn prop_random_gauntlet_cell_preserves_invariants_end_to_end() {
    use vpe::bench_harness::gauntlet;
    use vpe::bench_harness::report::REQUIRED_COLUMNS;

    // A cell is itself a bundle of assertions: `run_cell` errors unless
    // queue invariants held on every sweep, every admitted call resolved
    // exactly once, and per-target charged joules equal watts x busy
    // time.  The property samples random (cell, seed, load) points and
    // demands the bundle holds — and that the row it yields carries the
    // full shared schema.
    prop::check("gauntlet cell end-to-end", 6, |g| {
        let matrix = gauntlet::default_matrix();
        let cell = g.choose(&matrix).clone();
        let mut cfg = gauntlet::GauntletConfig::smoke();
        cfg.seed = g.u64_in(0, u64::MAX - 1);
        cfg.calls_per_cell = g.usize_in(16, 48);
        let row = gauntlet::run_cell(&cell, &cfg).map_err(|e| e.to_string())?;
        assert_prop(row.cell() == cell.id(), "row must be keyed by its cell id")?;
        for col in REQUIRED_COLUMNS {
            assert_prop(
                row.f64(col).is_some(),
                format!("cell {}: required column '{col}' missing", cell.id()),
            )?;
        }
        let avail = row.f64("availability").expect("checked");
        assert_prop(
            avail > 0.0 && avail <= 1.0,
            format!("availability {avail} outside (0, 1]"),
        )?;
        assert_prop(
            row.f64("throughput_calls_per_s").expect("checked") > 0.0,
            "throughput must be positive",
        )
    });
}
