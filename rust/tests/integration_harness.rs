//! Integration tests over the bench harness: the paper's evaluation
//! regenerates with the right shape end to end.

use vpe::bench_harness::{fig2, fig3, table1};
use vpe::platform::dm3730;
use vpe::workloads::WorkloadKind;

#[test]
fn table1_rows_cover_all_workloads_in_paper_order() {
    let rows = table1::table1(10, false).unwrap();
    let kinds: Vec<WorkloadKind> = rows.iter().map(|r| r.kind).collect();
    assert_eq!(kinds, WorkloadKind::ALL.to_vec());
}

#[test]
fn table1_render_includes_paper_comparison_columns() {
    let rows = table1::table1(6, false).unwrap();
    let md = table1::render(&rows).to_markdown();
    assert!(md.contains("paper speedup"));
    assert!(md.contains("reverted to ARM"));
    assert!(md.contains("31.9x"));
}

#[test]
fn fig2b_curve_has_the_paper_shape() {
    // Flat DSP plateau, monotone ARM curve, single crossover.
    let (points, _) = fig2::fig2b(&fig2::default_sizes(), 3, 9);
    let mut crossings = 0;
    for w in points.windows(2) {
        assert!(w[1].arm_ms > w[0].arm_ms, "ARM curve must grow");
        if w[0].winner() != w[1].winner() {
            crossings += 1;
        }
    }
    assert_eq!(crossings, 1, "exactly one ARM->DSP crossover");
    assert_eq!(points.first().unwrap().winner(), dm3730::ARM);
    assert_eq!(points.last().unwrap().winner(), dm3730::DSP);
}

#[test]
fn fig3_ablation_period_trades_bursts_for_fps() {
    let fast = fig3::fig3_with_period(150, 30, 2).unwrap();
    let slow = fig3::fig3_with_period(150, 30, 32).unwrap();
    assert!(fast.bursts > slow.bursts);
    // More frequent analysis -> more profiler CPU work -> lower fps.
    assert!(fast.fps_after < slow.fps_after);
}

#[test]
fn fig3_grant_frame_controls_the_transition() {
    for grant in [10usize, 50] {
        let s = fig3::fig3(120, grant, false).unwrap();
        let off = s.offload_frame.unwrap();
        assert!(off >= grant && off < grant + 15, "grant {grant}: offload at {off}");
    }
}
