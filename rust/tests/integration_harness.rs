//! Integration tests over the bench harness: the paper's evaluation
//! regenerates with the right shape end to end.

use vpe::bench_harness::{fig2, fig3, table1};
use vpe::platform::dm3730;
use vpe::workloads::WorkloadKind;

#[test]
fn table1_rows_cover_all_workloads_in_paper_order() {
    let rows = table1::table1(10, false).unwrap();
    let kinds: Vec<WorkloadKind> = rows.iter().map(|r| r.kind).collect();
    assert_eq!(kinds, WorkloadKind::ALL.to_vec());
}

#[test]
fn table1_render_includes_paper_comparison_columns() {
    let rows = table1::table1(6, false).unwrap();
    let md = table1::render(&rows).to_markdown();
    assert!(md.contains("paper speedup"));
    assert!(md.contains("reverted to ARM"));
    assert!(md.contains("31.9x"));
}

#[test]
fn fig2b_curve_has_the_paper_shape() {
    // Flat DSP plateau, monotone ARM curve, single crossover.
    let (points, _) = fig2::fig2b(&fig2::default_sizes(), 3, 9);
    let mut crossings = 0;
    for w in points.windows(2) {
        assert!(w[1].arm_ms > w[0].arm_ms, "ARM curve must grow");
        if w[0].winner() != w[1].winner() {
            crossings += 1;
        }
    }
    assert_eq!(crossings, 1, "exactly one ARM->DSP crossover");
    assert_eq!(points.first().unwrap().winner(), dm3730::ARM);
    assert_eq!(points.last().unwrap().winner(), dm3730::DSP);
}

#[test]
fn fig3_ablation_period_trades_bursts_for_fps() {
    let fast = fig3::fig3_with_period(150, 30, 2).unwrap();
    let slow = fig3::fig3_with_period(150, 30, 32).unwrap();
    assert!(fast.bursts > slow.bursts);
    // More frequent analysis -> more profiler CPU work -> lower fps.
    assert!(fast.fps_after < slow.fps_after);
}

#[test]
fn fig3_grant_frame_controls_the_transition() {
    for grant in [10usize, 50] {
        let s = fig3::fig3(120, grant, false).unwrap();
        let off = s.offload_frame.unwrap();
        assert!(off >= grant && off < grant + 15, "grant {grant}: offload at {off}");
    }
}

#[test]
fn gauntlet_runs_a_filtered_cell_through_the_public_api_deterministically() {
    use vpe::bench_harness::{gauntlet, GauntletConfig};

    // One cell, twice, through exactly the surface the CLI verb uses:
    // filter -> run -> serialize.  The texts must match byte for byte.
    let mut cfg = GauntletConfig::smoke();
    cfg.calls_per_cell = 24;
    cfg.filter = Some("bursty-skewed-fast-t04-edp-faults".into());
    assert_eq!(cfg.cells().len(), 1, "the filter must select exactly one cell");
    let a = gauntlet::run(&cfg).unwrap().to_json_string().unwrap();
    let b = gauntlet::run(&cfg).unwrap().to_json_string().unwrap();
    assert_eq!(a, b, "same-seed filtered run must serialize bit-identically");
}

#[test]
fn gauntlet_artifact_roundtrips_and_feeds_the_trajectory_table() {
    use vpe::bench_harness::{gauntlet, trajectory_table, GauntletConfig, ParsedBench};

    let mut cfg = GauntletConfig::smoke();
    cfg.calls_per_cell = 24;
    cfg.filter = Some("t04-latency".into());
    let cells = cfg.cells().len();
    assert!(cells >= 2, "the filter must keep a clean and a faulted cell");
    let text = gauntlet::run(&cfg).unwrap().to_json_string().unwrap();

    // The artifact parses back under the shared schema, every required
    // column numeric on every row.
    let parsed = ParsedBench::parse(&text).unwrap();
    assert_eq!(parsed.example, "gauntlet");
    assert_eq!(parsed.cells.len(), cells);

    // And the same parsed form drives the CI trajectory comparison:
    // identical artifacts diff to all-zero deltas, never "(new)".
    let table = trajectory_table(&ParsedBench::parse(&text).unwrap(), &parsed);
    assert!(!table.contains("(new)"), "identical artifacts must not report new cells");
    assert!(!table.contains("(dropped)"), "identical artifacts must not drop cells");
}
