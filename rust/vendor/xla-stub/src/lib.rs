//! Stub of the `xla` (xla_extension 0.5.x) API surface the VPE runtime
//! uses.  It exists so `cargo` can resolve the optional `xla` dependency
//! in offline builds; every constructor fails at run time with a clear
//! message.  Builds that vendor the real bindings replace this crate via
//! `[patch]` (or by swapping the path in Cargo.toml) and get actual PJRT
//! execution with no source changes.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

fn stub_err<T>() -> Result<T> {
    Err(Error(
        "xla stub: PJRT is unavailable in this build (vendor the real xla crate to enable it)"
            .to_string(),
    ))
}

/// Element types the runtime moves across the boundary.
pub trait NativeType: Copy {}
impl NativeType for i32 {}
impl NativeType for f32 {}

/// Host literal (stub: never holds data).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        stub_err()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        stub_err()
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        stub_err()
    }
}

/// Device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub_err()
    }
}

/// Loaded executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err()
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub_err()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub_err()
    }
}

/// Parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto> {
        stub_err()
    }
}

/// XLA computation wrapper.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
