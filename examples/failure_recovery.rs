//! Run-time failure recovery — the paper's §1 claim that VPE "can
//! dynamically react to changes in the context of execution, for example
//! resources that [...] experience an hardware failure".
//!
//! Timeline:
//!   phase 1: matmul runs hot, VPE offloads it to the DSP;
//!   phase 2: the DSP dies mid-run — the very next call transparently
//!            fails over to the ARM core (no error reaches the app);
//!   phase 3: the DSP comes back — VPE re-profiles and re-offloads;
//!   phase 4: the failure hits the *async* path — queued submits are
//!            mid-flight when a scripted fault kills the DSP; the
//!            salvage machinery retries them on the host and the event
//!            log shows the recovery in order:
//!            TargetFailed -> DispatchRetried -> TargetRecovered.
//!
//! `cargo run --release --example failure_recovery`

use vpe::coordinator::{CallOutcome, Vpe, VpeConfig, VpeEvent};
use vpe::platform::{dm3730, TargetId};
use vpe::sim::FaultInjector;
use vpe::workloads::WorkloadKind;

fn main() -> vpe::Result<()> {
    let mut vpe = Vpe::new(VpeConfig::sim_only())?;
    let f = vpe.register_workload(WorkloadKind::Matmul)?;

    println!("phase 1: warm up + offload");
    vpe.run(f, 15)?;
    assert_eq!(vpe.current_target(f)?, dm3730::DSP);
    println!("  matmul is on the DSP after {} calls", 15);

    println!("phase 2: DSP hardware failure injected");
    vpe.fail_target(dm3730::DSP)?;
    let recs = vpe.run(f, 10)?;
    // Every call still succeeded — on the host.
    assert!(recs.iter().all(|r| r.target == TargetId::HOST));
    assert_eq!(vpe.current_target(f)?, TargetId::HOST);
    println!("  10/10 calls served locally, zero failures surfaced to the app");

    println!("phase 3: DSP restored");
    vpe.heal_target(dm3730::DSP);
    vpe.run(f, 15)?;
    assert_eq!(vpe.current_target(f)?, dm3730::DSP);
    println!("  VPE re-profiled and re-offloaded");

    println!("phase 4: mid-flight failure on the async submit/drain path");
    let mark = vpe.events().iter().count();
    // Script the fault in virtual time: the DSP dies 1 ms into the
    // queued work's run and heals 50 ms later — while retried work is
    // still draining on the host.
    let now = vpe.clock().now_ns();
    vpe.set_fault_injector(
        FaultInjector::new(9)
            .fail_at(now + 1_000_000, dm3730::DSP)
            .heal_at(now + 50_000_000, dm3730::DSP),
    );
    for _ in 0..4 {
        vpe.submit(f)?;
    }
    let recs = vpe.drain()?;
    assert_eq!(recs.len(), 4);
    assert!(
        recs.iter().all(|r| r.outcome == CallOutcome::Ok),
        "no error reaches the app: every queued call still resolves Ok"
    );
    assert!(
        recs.iter().any(|r| r.target == TargetId::HOST),
        "salvaged work must have landed on the survivor"
    );
    let (retries, rerouted, _, failed) = vpe.recovery_counters();
    assert!(retries + rerouted >= 1, "salvage must actually engage");
    assert_eq!(failed, 0);
    // The recovery events appear, in order.
    let order: Vec<&str> = vpe
        .events()
        .iter()
        .skip(mark)
        .filter_map(|(_, e)| match e {
            VpeEvent::TargetFailed { .. } => Some("failed"),
            VpeEvent::DispatchRetried { .. } => Some("retried"),
            VpeEvent::TargetRecovered { .. } => Some("recovered"),
            _ => None,
        })
        .collect();
    let fi = order.iter().position(|s| *s == "failed").expect("TargetFailed logged");
    let ri = order.iter().position(|s| *s == "retried").expect("DispatchRetried logged");
    let hi = order.iter().rposition(|s| *s == "recovered").expect("TargetRecovered logged");
    assert!(fi < ri && ri < hi, "recovery events out of order: {order:?}");
    println!("  4/4 queued calls salvaged; event order: {}", order.join(" -> "));

    println!("\nevent trace:\n{}", vpe.events().to_text());
    Ok(())
}
