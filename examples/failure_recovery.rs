//! Run-time failure recovery — the paper's §1 claim that VPE "can
//! dynamically react to changes in the context of execution, for example
//! resources that [...] experience an hardware failure".
//!
//! Timeline:
//!   phase 1: matmul runs hot, VPE offloads it to the DSP;
//!   phase 2: the DSP dies mid-run — the very next call transparently
//!            fails over to the ARM core (no error reaches the app);
//!   phase 3: the DSP comes back — VPE re-profiles and re-offloads.
//!
//! `cargo run --release --example failure_recovery`

use vpe::coordinator::{Vpe, VpeConfig};
use vpe::platform::{dm3730, TargetId};
use vpe::workloads::WorkloadKind;

fn main() -> vpe::Result<()> {
    let mut vpe = Vpe::new(VpeConfig::sim_only())?;
    let f = vpe.register_workload(WorkloadKind::Matmul)?;

    println!("phase 1: warm up + offload");
    vpe.run(f, 15)?;
    assert_eq!(vpe.current_target(f)?, dm3730::DSP);
    println!("  matmul is on the DSP after {} calls", 15);

    println!("phase 2: DSP hardware failure injected");
    vpe.soc_mut().fail_target(dm3730::DSP);
    let recs = vpe.run(f, 10)?;
    // Every call still succeeded — on the host.
    assert!(recs.iter().all(|r| r.target == TargetId::HOST));
    assert_eq!(vpe.current_target(f)?, TargetId::HOST);
    println!("  10/10 calls served locally, zero failures surfaced to the app");

    println!("phase 3: DSP restored");
    vpe.soc_mut().heal_target(dm3730::DSP);
    vpe.run(f, 15)?;
    assert_eq!(vpe.current_target(f)?, dm3730::DSP);
    println!("  VPE re-profiled and re-offloaded");

    println!("\nevent trace:\n{}", vpe.events().to_text());
    Ok(())
}
