//! The Fig 3 demonstrator, end to end: a real multi-threaded video
//! pipeline whose convolution stage runs under VPE.
//!
//! Three OS threads connected by channels, mirroring the paper's
//! process topology (OpenCV decode/display processes + the convolution
//! process under VPE):
//!
//!   decoder ──frames──▶ convolution (VPE) ──edges──▶ display/metrics
//!
//! The decoder synthesizes a deterministic 128x128 video (a bright
//! square orbiting over a gradient); the convolution applies a Laplacian
//! contour kernel — *really computed* through the PJRT artifact when
//! `make artifacts` has been run (every frame is also checked against
//! the pure-Rust convolution); the display thread verifies frames and
//! accumulates the two Fig 3 meters (frame rate, CPU load).
//!
//! Timing model: the simulated DM3730 clock (paper-scale 600x600 frame,
//! 9x9 kernel, decode/IPC/display stage costs) produces the paper's
//! numbers; host wall-clock times of the real PJRT convolutions are
//! reported alongside.
//!
//! `cargo run --release --example video_pipeline [-- --frames N --grant N]`

use std::sync::mpsc;

use vpe::bench_harness::fig3::stage;
use vpe::coordinator::{Vpe, VpeConfig};
use vpe::platform::TargetId;
use vpe::workloads::{conv2d, shapes, PaperScale, Tensor};

/// Synthesize frame `i`: gradient background + bright orbiting square.
fn synth_frame(i: usize, h: usize, w: usize) -> Vec<i32> {
    let mut px = vec![0i32; h * w];
    for y in 0..h {
        for x in 0..w {
            px[y * w + x] = ((x + y + i) % 13) as i32; // moving gradient
        }
    }
    // Orbiting 16x16 bright square.
    let cy = h / 2 + ((i as f64 / 10.0).sin() * (h as f64 / 4.0)) as usize;
    let cx = w / 2 + ((i as f64 / 10.0).cos() * (w as f64 / 4.0)) as usize;
    for y in cy.saturating_sub(8)..(cy + 8).min(h) {
        for x in cx.saturating_sub(8)..(cx + 8).min(w) {
            px[y * w + x] = 96;
        }
    }
    px
}

struct Done {
    frame: usize,
    target: TargetId,
    sim_frame_ms: f64,
    cpu_busy_ms: f64,
    wall_conv_ms: Option<f64>,
    verified: Option<bool>,
    edge_energy: i64,
}

fn main() -> vpe::Result<()> {
    let args = vpe::util::cli::Args::parse(std::env::args().skip(1))?;
    let total_frames: usize = args.opt("frames", 150)?;
    let grant: usize = args.opt("grant", 40)?;
    args.finish()?;

    let (h, w, k) = (shapes::CONV_H, shapes::CONV_W, shapes::CONV_K);
    let kernel = conv2d::laplacian3();

    // -- decoder thread -----------------------------------------------------
    let (frame_tx, frame_rx) = mpsc::sync_channel::<(usize, Vec<i32>)>(4);
    let decoder = std::thread::spawn(move || {
        for i in 0..total_frames {
            let px = synth_frame(i, h, w);
            if frame_tx.send((i, px)).is_err() {
                break;
            }
        }
    });

    // -- convolution thread (VPE lives here) --------------------------------
    let (done_tx, done_rx) = mpsc::sync_channel::<Done>(4);
    let kernel_conv = kernel.clone();
    let conv_thread = std::thread::spawn(move || -> vpe::Result<()> {
        // Prefer a numerics-producing backend (PJRT artifacts or the
        // pure-Rust references); fall back to simulation-only.
        let mut cfg = VpeConfig::default();
        cfg.sampler.enabled = false; // VPE not yet granted the right to act
        let mut vpe = match Vpe::new(cfg) {
            Ok(v) => v,
            Err(_) => {
                eprintln!("(artifacts missing — conv runs simulation-only)");
                let mut c = VpeConfig::sim_only();
                c.sampler.enabled = false;
                Vpe::new(c)?
            }
        };
        // Register the convolution: artifact-shape numerics, paper-scale
        // costs (600x600 frame, 9x9 contour kernel).
        let mut inst = conv2d::instance(0xF16_3);
        inst.scale = PaperScale {
        items: stage::conv_items(),
        param_bytes: 48,
        payload_bytes: 2 * stage::FRAME_W * stage::FRAME_H * 4 + 81 * 4,
    };
        let conv = vpe.register_instance(inst)?;

        while let Ok((i, px)) = frame_rx.recv() {
            if i == grant {
                // "After a predefined time interval, VPE is granted the
                // right to automatically optimize the execution."
                vpe.sampler_mut().set_enabled(true);
            }
            let expected = conv2d::reference(&px, h, w, &kernel_conv, k);
            let inputs = [
                Tensor::i32(vec![h, w], px),
                Tensor::i32(vec![k, k], kernel_conv.clone()),
            ];
            let (rec, out) = vpe.call_with(conv, &inputs)?;
            let (verified, edge_energy) = match &out {
                Some(t) => {
                    let got = t.as_i32().expect("conv output is i32");
                    (
                        Some(got == expected.as_slice()),
                        got.iter().map(|&v| (v as i64).abs()).sum(),
                    )
                }
                None => (None, expected.iter().map(|&v| (v as i64).abs()).sum()),
            };
            let conv_ms = (rec.exec_ns + rec.profiling_ns) as f64 / 1e6;
            let cpu_stage_ms = stage::DECODE_MS + stage::IPC_MS + stage::DISPLAY_MS;
            let (sim_frame_ms, cpu_busy_ms) = if rec.target.is_host() {
                (cpu_stage_ms + conv_ms, cpu_stage_ms + conv_ms)
            } else {
                let prof_ms = rec.profiling_ns as f64 / 1e6;
                let span =
                    stage::DECODE_MS.max(conv_ms) + stage::IPC_MS + stage::DISPLAY_MS;
                (span, cpu_stage_ms + prof_ms)
            };
            let done = Done {
                frame: i,
                target: rec.target,
                sim_frame_ms,
                cpu_busy_ms,
                wall_conv_ms: rec.wall.map(|d| d.as_secs_f64() * 1e3),
                verified,
                edge_energy,
            };
            if done_tx.send(done).is_err() {
                break;
            }
        }
        Ok(())
    });

    // -- display / metrics thread (main) -------------------------------------
    let mut before = Vec::new();
    let mut after = Vec::new();
    let mut mismatches = 0usize;
    let mut offload_frame = None;
    let wall_start = std::time::Instant::now();
    while let Ok(d) = done_rx.recv() {
        if d.verified == Some(false) {
            mismatches += 1;
        }
        if !d.target.is_host() && offload_frame.is_none() {
            offload_frame = Some(d.frame);
            println!(">>> frame {:>4}: VPE moved the convolution off the host", d.frame);
        }
        if d.frame % 25 == 0 {
            println!(
                "frame {:>4}: conv on {:<14} sim {:>6.1} ms/frame ({:>4.1} fps sim)  cpu {:>3.0}%  edges {}{}",
                d.frame,
                if d.target.is_host() { "ARM Cortex-A8" } else { "C64x+ DSP" },
                d.sim_frame_ms,
                1e3 / d.sim_frame_ms,
                (d.cpu_busy_ms / d.sim_frame_ms).min(1.0) * 100.0,
                d.edge_energy,
                d.wall_conv_ms.map(|m| format!("  [PJRT {m:.2} ms]")).unwrap_or_default(),
            );
        }
        let rec = (d.sim_frame_ms, d.cpu_busy_ms);
        if d.target.is_host() {
            before.push(rec);
        } else {
            after.push(rec);
        }
    }
    let wall_total = wall_start.elapsed();
    decoder.join().expect("decoder panicked");
    conv_thread.join().expect("conv thread panicked")?;

    let mean_fps = |xs: &[(f64, f64)]| 1e3 / (xs.iter().map(|x| x.0).sum::<f64>() / xs.len() as f64);
    let mean_cpu = |xs: &[(f64, f64)]| {
        xs.iter().map(|x| (x.1 / x.0).min(1.0)).sum::<f64>() / xs.len() as f64
    };
    println!("\n=== Fig 3 summary (simulated DM3730 clock) ===");
    if !before.is_empty() && !after.is_empty() {
        let (fb, fa) = (mean_fps(&before), mean_fps(&after));
        println!("frame rate: {fb:.2} fps -> {fa:.2} fps  ({:.1}x; paper: ~4x)", fa / fb);
        println!(
            "CPU load:   {:.0}% -> {:.0}%  (paper: halved)",
            mean_cpu(&before) * 100.0,
            mean_cpu(&after) * 100.0
        );
    }
    println!(
        "frames: {} ({} on ARM, {} on DSP), offload at frame {:?}",
        before.len() + after.len(),
        before.len(),
        after.len(),
        offload_frame
    );
    println!(
        "real pipeline wall time: {:.2} s ({:.1} frames/s of actual PJRT compute)",
        wall_total.as_secs_f64(),
        (before.len() + after.len()) as f64 / wall_total.as_secs_f64()
    );
    println!("frame verification mismatches: {mismatches}");
    assert_eq!(mismatches, 0, "convolution outputs must match the Rust reference");
    Ok(())
}
