//! Size-adaptive dispatch — the paper's §5.2 future-work item, working.
//!
//! "We could easily learn automatically a correlation between the size
//! of the matrix passed as a parameter and the performance achieved —
//! [using] a simple decision tree — and ground future decisions upon
//! this criteria."
//!
//! Phase 1 (explore): run matmuls of many sizes on both targets and
//! collect (size, winner) observations — the measurements VPE's profiler
//! produces anyway.
//! Phase 2 (learn): fit the decision tree; its root split *is* the
//! Fig 2b crossover.
//! Phase 3 (exploit): dispatch unseen sizes by prediction — no warm-up,
//! no blind trial, each call lands on the right target immediately.

use vpe::coordinator::decision_tree::{DecisionTree, Observation};
use vpe::platform::{dm3730, Soc, TargetId};
use vpe::sim::SimRng;
use vpe::util::cli::Args;
use vpe::workloads::{matmul_scale, WorkloadKind};

fn measure(soc: &Soc, n: u64, target: TargetId, rng: &mut SimRng) -> f64 {
    let scale = matmul_scale(n);
    let base = soc
        .call_scaled_ns(WorkloadKind::Matmul, &scale, target)
        .expect("healthy targets") as f64;
    base * (1.0 + 0.008 * rng.standard_normal())
}

fn main() -> vpe::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let reps: usize = args.opt("reps", 4)?;
    args.finish()?;

    let soc = Soc::dm3730();
    let mut rng = SimRng::seeded(0xADA9);

    // -- Phase 1: explore -------------------------------------------------
    let train_sizes = [12u64, 20, 32, 48, 64, 80, 96, 120, 160, 240, 320, 480];
    let mut obs = Vec::new();
    for &n in &train_sizes {
        for _ in 0..reps {
            let arm = measure(&soc, n, dm3730::ARM, &mut rng);
            let dsp = measure(&soc, n, dm3730::DSP, &mut rng);
            obs.push(Observation {
                size: n as f64,
                best: if dsp < arm { dm3730::DSP } else { dm3730::ARM },
            });
        }
    }
    println!("phase 1: {} observations across {} sizes", obs.len(), train_sizes.len());

    // -- Phase 2: learn ---------------------------------------------------
    let tree = DecisionTree::fit(&obs, 4, 3);
    println!(
        "phase 2: decision tree fitted (train accuracy {:.0}%, learned crossover N = {})",
        tree.accuracy(&obs) * 100.0,
        tree.root_threshold().map(|t| format!("{t:.0}")).unwrap_or("-".into()),
    );

    // -- Phase 3: exploit on unseen sizes ----------------------------------
    let test_sizes = [16u64, 50, 75, 91, 110, 200, 400, 500];
    println!("\nphase 3: dispatch-by-prediction on unseen sizes");
    println!("{:>5} {:>12} {:>12} {:>12} {:>10} {:>8}", "N", "ARM ms", "DSP ms", "predicted", "actual", "ok");
    let mut correct = 0;
    for &n in &test_sizes {
        let arm = measure(&soc, n, dm3730::ARM, &mut rng) / 1e6;
        let dsp = measure(&soc, n, dm3730::DSP, &mut rng) / 1e6;
        let predicted = tree.predict(n as f64);
        let actual = if dsp < arm { dm3730::DSP } else { dm3730::ARM };
        let ok = predicted == actual;
        correct += ok as usize;
        println!(
            "{n:>5} {arm:>12.1} {dsp:>12.1} {:>12} {:>10} {:>8}",
            short(predicted),
            short(actual),
            if ok { "yes" } else { "NO" }
        );
    }
    println!(
        "\n{}/{} unseen sizes dispatched correctly — the warm-up phase is gone.",
        correct,
        test_sizes.len()
    );
    assert!(correct >= test_sizes.len() - 1, "tree generalizes poorly");
    Ok(())
}

fn short(t: TargetId) -> &'static str {
    if t.is_host() { "ARM" } else { "DSP" }
}
