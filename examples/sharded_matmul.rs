//! Sharded fan-out, end to end — the tentpole's acceptance demo.
//!
//! One 2000x2000 matmul is too big for any single unit to finish
//! quickly, but its row blocks are independent.  This example builds a
//! 5-unit platform (ARM host + C64x+ DSP + three data-registered
//! accelerators), lets the planner split the call across them (sized by
//! the cost model and the queue state), runs the shards concurrently
//! through the dispatch queue, and reassembles the output:
//!
//! 1. the reassembled 2000x2000 product is verified bit-exactly against
//!    the pure-Rust reference;
//! 2. the sharded call completes on the sim clock >= 2x faster than the
//!    best single-unit dispatch of the same call;
//! 3. per-target serialization still holds across all shard windows.
//!
//! `cargo run --release --example sharded_matmul`

use vpe::coordinator::{Vpe, VpeConfig};
use vpe::platform::{TargetId, TargetSpec, TransferModel, Transport};
use vpe::workloads::{generator, matmul, matmul_scale, Tensor, WorkloadInstance, WorkloadKind};

fn main() -> vpe::Result<()> {
    let mut cfg = VpeConfig::default(); // reference backend: real numerics
    cfg.exec_noise_frac = 0.0; // deterministic timings for the printout
    let mut vpe = Vpe::new(cfg)?;

    // -- the platform is data: three extra units join as specs + rates --------
    for (name, fixed_ns, rate) in [
        ("vector-unit", 5_000_000u64, 0.35),
        ("gpu-a", 30_000_000, 0.20),
        ("gpu-b", 30_000_000, 0.25),
    ] {
        let id = vpe.soc_mut().add_target(
            TargetSpec::new(name, 1_200_000_000).with_issue_width(16).with_transport(
                Transport::SharedMemory(TransferModel {
                    dispatch_fixed_ns: fixed_ns,
                    per_param_byte_ns: 1.0,
                }),
            ),
        );
        vpe.soc_mut().cost.set_rate(WorkloadKind::Matmul, id, rate);
    }
    println!("platform: {} compute units", vpe.soc().registry.len());
    for (id, spec) in vpe.soc().targets() {
        println!("  [{id}] {}", spec.name);
    }
    assert!(vpe.soc().registry.len() >= 5, "host + DSP + 3 registered units");

    // -- a 2000x2000 matmul instance ------------------------------------------
    // The expected output comes from the cache-blocked reference (the
    // naive ijk loop would dominate this example's wall time).
    let n = 2000usize;
    println!("\nbuilding the 2000x2000 instance (reference product on the host)...");
    let a = generator::ints(n * n, -8, 8, 0xA);
    let b = generator::ints(n * n, -8, 8, 0xB);
    let expected = matmul::reference_blocked(&a, &b, n, 64);
    let f = vpe.register_instance(WorkloadInstance {
        kind: WorkloadKind::Matmul,
        scale: matmul_scale(n as u64),
        inputs: vec![Tensor::i32(vec![n, n], a), Tensor::i32(vec![n, n], b)],
        expected: Tensor::i32(vec![n, n], expected),
        artifact_naive: "matmul2000__naive".into(),
        artifact_dsp: "matmul2000__dsp".into(),
    })?;

    // Best single-unit dispatch of the same call (noise-free price).
    let scale = matmul_scale(n as u64);
    let (mut best_single, mut best_target) = (u64::MAX, TargetId::HOST);
    for (id, _) in vpe.soc().targets() {
        if let Ok(ns) = vpe.soc().call_scaled_ns(WorkloadKind::Matmul, &scale, id) {
            if ns < best_single {
                best_single = ns;
                best_target = id;
            }
        }
    }
    println!(
        "best single-unit dispatch: [{best_target}] {} at {:.1} ms (sim)",
        vpe.target_name(best_target),
        best_single as f64 / 1e6
    );

    // -- the sharded call ------------------------------------------------------
    let rec = vpe.call_sharded(f)?;
    println!("\nsharded call: {} shards, retired as one aggregate record", rec.shards);
    let windows = vpe.events().shard_windows();
    for (t, start, complete) in &windows {
        println!(
            "  shard on [{t}] {:<24} start {:>9.3} ms  end {:>9.3} ms",
            vpe.target_name(*t),
            *start as f64 / 1e6,
            *complete as f64 / 1e6,
        );
    }
    let makespan_ms = rec.exec_ns as f64 / 1e6;
    let speedup = best_single as f64 / rec.exec_ns as f64;
    println!(
        "\nmakespan {makespan_ms:.1} ms vs best single unit {:.1} ms -> {speedup:.2}x",
        best_single as f64 / 1e6
    );

    // 1. The reassembled output is bit-exact against the reference.
    assert_eq!(rec.output_ok, Some(true), "reassembled output must verify");
    println!("reassembled output verified against the reference: OK");

    // 2. >= 2x faster than the best single-unit dispatch, across >= 4 units.
    assert!(rec.shards >= 4, "must fan out across >= 4 units, got {}", rec.shards);
    assert!(
        speedup >= 2.0,
        "sharded call must be >= 2x faster than the best single unit ({speedup:.2}x)"
    );

    // 3. Per-target serialization across all shard windows.
    for (id, _) in vpe.soc().targets() {
        let mut on: Vec<_> = windows.iter().filter(|w| w.0 == id).collect();
        on.sort_by_key(|w| w.1);
        for p in on.windows(2) {
            assert!(p[1].1 >= p[0].2, "unit {id} double-booked");
        }
    }
    assert_eq!(vpe.in_flight(), 0);
    assert_eq!(vpe.soc().shared.used_bytes(), 0, "staging must be freed");

    println!("\n{}", vpe.report());
    println!(
        "one 2000x2000 call split across {} units, reassembled, verified, {speedup:.2}x over the best single unit.",
        rec.shards
    );
    Ok(())
}
