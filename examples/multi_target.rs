//! N-target dispatch, end to end — the refactor's acceptance demo.
//!
//! The paper's prototype pairs one ARM host with one DSP; its outlook
//! (and the ROADMAP north-star) is *many* heterogeneous units.  This
//! example builds a 4-unit platform **purely from data** — the DM3730
//! pair plus a NEON-class vector engine and a GPU-class accelerator,
//! each a `TargetSpec` registration + cost-model rows, zero coordinator
//! or policy changes — then:
//!
//! 1. lets the unchanged blind-offload policy route three hot functions
//!    to three different units (each lands where it wins);
//! 2. switches to the queued call path (`submit`/`drain`) and issues
//!    bursts whose dispatches execute **concurrently** on the sim
//!    clock, retiring in completion order;
//! 3. prints the in-flight timeline and verifies ≥2 dispatches were in
//!    flight at once with overlapping execution windows.
//!
//! `cargo run --release --example multi_target`

use vpe::coordinator::{Vpe, VpeConfig};
use vpe::platform::{TargetSpec, TransferModel, Transport};
use vpe::workloads::WorkloadKind;

fn main() -> vpe::Result<()> {
    let mut cfg = VpeConfig::sim_only();
    // Three hot functions share the cycle budget; let the cooler ones
    // still reach nomination.
    cfg.detector.share_threshold = 0.02;
    let mut vpe = Vpe::new(cfg)?;

    // -- the platform is data -------------------------------------------------
    // A tightly-coupled on-die vector engine: tiny dispatch setup.
    let neon = vpe.soc_mut().add_target(
        TargetSpec::new("NEON-class vector unit", 1_000_000_000)
            .with_issue_width(4)
            .with_transport(Transport::SharedMemory(TransferModel {
                dispatch_fixed_ns: 5_000_000,
                per_param_byte_ns: 1.0,
            })),
    );
    // A GPU-class accelerator: bigger setup, massive throughput.
    let gpu = vpe.soc_mut().add_target(
        TargetSpec::new("GPU-class accelerator", 1_200_000_000)
            .with_issue_width(32)
            .with_transport(Transport::SharedMemory(TransferModel {
                dispatch_fixed_ns: 30_000_000,
                per_param_byte_ns: 1.0,
            })),
    );
    // Cost-model rows: what each new unit is good at (ns per item).
    let cost = &mut vpe.soc_mut().cost;
    cost.set_rate(WorkloadKind::Conv2d, neon, 0.05); // streams stencils
    cost.set_rate(WorkloadKind::Matmul, neon, 3.0); //  ...but matmul only so-so
    cost.set_rate(WorkloadKind::Matmul, gpu, 0.2); //   matmul monster
    println!("platform: {} compute units", vpe.soc().registry.len());
    for (id, spec) in vpe.soc().targets() {
        println!("  [{id}] {}", spec.name);
    }
    assert!(vpe.soc().registry.len() >= 4, "host + >=3 units");

    // -- phase 1: each hot function finds its own unit ------------------------
    let mm = vpe.register_matmul(500)?;
    let conv = vpe.register_workload(WorkloadKind::Conv2d)?;
    let dot = vpe.register_workload(WorkloadKind::Dotprod)?;
    for _ in 0..30 {
        vpe.call(mm)?;
        vpe.call(conv)?;
        vpe.call(dot)?;
    }
    println!("\nphase 1 — steady-state placement after 30 iterations:");
    for (f, label) in [(mm, "matmul 500x500"), (conv, "conv2d"), (dot, "dotprod")] {
        let t = vpe.current_target(f)?;
        println!("  {label:<16} -> [{t}] {}", vpe.target_name(t));
    }
    assert_eq!(vpe.current_target(mm)?, gpu);
    assert_eq!(vpe.current_target(conv)?, neon);
    assert!(!vpe.current_target(dot)?.is_host(), "dotprod must leave the host");

    // -- phase 2: concurrent in-flight dispatches -----------------------------
    println!("\nphase 2 — queued bursts (submit/drain, completion-ordered):");
    let mut all = Vec::new();
    for burst in 0..3 {
        for f in [mm, conv, dot] {
            vpe.submit(f)?;
        }
        let in_flight = vpe.in_flight();
        let recs = vpe.drain()?;
        println!("  burst {burst}: {in_flight} dispatches in flight, retired in order:");
        for r in &recs {
            println!(
                "    {:<14} on [{}] {:<24} start {:>9.3} ms  end {:>9.3} ms{}",
                vpe.kind_of(r.function).map(|k| k.name()).unwrap_or("?"),
                r.target,
                vpe.target_name(r.target),
                r.start_ns as f64 / 1e6,
                r.complete_ns as f64 / 1e6,
                if r.queued_ns() > 0 {
                    format!("  (queued {:.3} ms)", r.queued_ns() as f64 / 1e6)
                } else {
                    String::new()
                },
            );
        }
        all.extend(recs);
    }

    // ≥2 dispatches genuinely overlapped on the sim clock.
    let mut max_overlap = 0usize;
    for r in &all {
        let concurrent = all
            .iter()
            .filter(|o| o.start_ns < r.complete_ns && r.start_ns < o.complete_ns)
            .count();
        max_overlap = max_overlap.max(concurrent);
    }
    println!(
        "\nmax dispatches in flight: {} (peak {} concurrently executing)",
        vpe.max_in_flight(),
        max_overlap
    );
    assert!(vpe.max_in_flight() >= 2, "bursts must overlap in flight");
    assert!(max_overlap >= 2, "execution windows must overlap on the sim clock");

    // Per-target serialization still holds.
    for (id, _) in vpe.soc().targets() {
        let mut on: Vec<_> = all.iter().filter(|r| r.target == id).collect();
        on.sort_by_key(|r| r.start_ns);
        for w in on.windows(2) {
            assert!(w[1].start_ns >= w[0].complete_ns, "unit {id} double-booked");
        }
    }

    println!("\n{}", vpe.report());
    println!("three units joined as data (TargetSpec + cost rows); dispatches overlap; each function found its best unit.");
    Ok(())
}
