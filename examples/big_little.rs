//! big.LITTLE divergence proof — the energy axis's acceptance example.
//!
//! A heterogeneous platform where *no single placement is best*: a
//! hungry out-of-order "big" core (6x the host's speed at 5 W), a
//! frugal in-order "little" core (2x at 1 W), the calibrated DSP
//! (fast but 3 W), and the 2 W ARM host.  The same hot matmul is run
//! three times under three objectives, and the runs must disagree:
//!
//! - **latency** ([`BlindOffloadPolicy`]) races to the big core;
//! - **energy** ([`EnergyPolicy`]) settles on the little core — it is
//!   3x slower than big, but per call it burns 1 W x 138 ms = 138 mJ
//!   against big's 5 W x 46 ms = 230 mJ;
//! - **EDP** ([`EdpPolicy`]) lands back on the big core: the delay
//!   factor punishes little's slowness more than its frugality helps.
//!
//! Each run records a v4 trace; replaying it under the *same* policy
//! must reproduce the recorded decision sequence, total nanoseconds
//! and total nanojoules exactly (the trace carries the power-model
//! header, per-entry joules and the priced host baseline).  A what-if
//! table then re-prices the latency-optimal recording under every
//! objective, side by side in ms and mJ.
//!
//! Emits `BENCH_energy.json` through the shared
//! [`vpe::bench_harness::report`] writer — one row per objective with
//! placement, totals and replay-exactness, diffable across PRs (CI
//! uploads it per run).
//!
//! `cargo run --release --example big_little`

use vpe::bench_harness::{BenchReport, BenchRow, Metric};
use vpe::coordinator::policies_ext::{EdpPolicy, EnergyPolicy, EnergyPolicyConfig};
use vpe::coordinator::policy::{BlindOffloadPolicy, OffloadPolicy};
use vpe::coordinator::trace::{replay, Trace};
use vpe::coordinator::{Vpe, VpeConfig};
use vpe::platform::{dm3730, PowerModel, TargetId, TargetSpec, TransferModel, Transport};
use vpe::workloads::WorkloadKind;

/// Hot-loop iterations per objective run (enough to profile, decide
/// and settle into steady state).
const ITERS: usize = 40;

/// The asymmetric cores: (name, host-rate divisor, active W, idle W).
/// Rates divide the host's per-item cost, so big finishes a call ~6x
/// sooner than the host while drawing 5x the little core's power.
const CORES: [(&str, f64, u64, u64); 2] =
    [("big-core", 6.0, 5, 0), ("little-core", 2.0, 1, 0)];

/// One big.LITTLE coordinator: host + DSP powered, big/little added
/// with their own rates, transports and power models.
fn build_platform(policy: Box<dyn OffloadPolicy>) -> vpe::Result<(Vpe, [TargetId; 2])> {
    let mut vpe = Vpe::with_policy(VpeConfig::sim_only(), policy)?;
    vpe.soc_mut().registry.get_mut(dm3730::ARM)?.power = PowerModel::new(2, 0);
    vpe.soc_mut().registry.get_mut(dm3730::DSP)?.power = PowerModel::new(3, 0);
    let host_rate = vpe
        .soc()
        .cost
        .rate_ns(WorkloadKind::Matmul, dm3730::ARM)
        .expect("the host prices every paper workload");
    let mut ids = [dm3730::ARM; 2];
    for (i, (name, divisor, active, idle)) in CORES.into_iter().enumerate() {
        let id = vpe.soc_mut().add_target(
            TargetSpec::new(name, 1_500_000_000).with_transport(Transport::SharedMemory(
                TransferModel { dispatch_fixed_ns: 1_500_000, per_param_byte_ns: 1.0 },
            )),
        );
        vpe.soc_mut().registry.get_mut(id)?.power = PowerModel::new(active, idle);
        vpe.soc_mut().cost.set_rate(WorkloadKind::Matmul, id, host_rate / divisor);
        ids[i] = id;
    }
    Ok((vpe, ids))
}

/// Run the hot matmul under one objective's policy with tracing on;
/// return the settled placement, the recorded trace, the live joules
/// charged across the platform and the setup saved by batching.
fn run_objective(policy: Box<dyn OffloadPolicy>) -> vpe::Result<(TargetId, Trace, u64, u64)> {
    let (mut vpe, _) = build_platform(policy)?;
    vpe.enable_tracing();
    let f = vpe.register_workload(WorkloadKind::Matmul)?;
    vpe.run(f, ITERS)?;
    let placed = vpe.current_target(f)?;
    let trace = vpe.trace().expect("tracing enabled").clone();
    Ok((placed, trace, vpe.total_energy_nj(), vpe.saved_setup_ns()))
}

/// Same-policy replay: must reproduce the recorded decision sequence,
/// nanoseconds and nanojoules bit-for-bit.
fn assert_exact_replay(trace: &Trace, policy: &mut dyn OffloadPolicy) -> (f64, f64) {
    let out = replay(trace, policy);
    assert_eq!(out.diverged(), 0, "{}", out.divergence_report());
    assert_eq!(out.total_ns, trace.total_ns(), "replayed ns must match the recording");
    assert_eq!(
        out.total_energy_nj,
        trace.total_energy_nj(),
        "replayed joules must match the recording"
    );
    (out.total_ms, out.total_energy_nj as f64 / 1e6)
}

fn main() -> vpe::Result<()> {
    println!("== big.LITTLE: one workload, three objectives, three answers ==");
    println!("   (big 6x @ 5 W / little 2x @ 1 W / DSP @ 3 W / host @ 2 W)\n");

    let cfg = EnergyPolicyConfig::default();
    let runs: [(&str, Box<dyn OffloadPolicy>); 3] = [
        ("latency", Box::<BlindOffloadPolicy>::default()),
        ("energy", Box::new(EnergyPolicy::new(cfg))),
        ("edp", Box::new(EdpPolicy::new(cfg))),
    ];
    let mut placements: Vec<(String, TargetId, Trace, u64, u64)> = Vec::new();
    for (objective, policy) in runs {
        let (placed, trace, live_nj, saved_ns) = run_objective(policy)?;
        placements.push((objective.to_string(), placed, trace, live_nj, saved_ns));
    }

    // Names for printing, from any one of the (identical) platforms.
    let (probe, [big, little]) = build_platform(Box::<BlindOffloadPolicy>::default())?;
    let name = |id: TargetId| probe.soc().registry.get(id).map(|s| s.name.clone());

    println!("objective   settled on      recorded ms  recorded mJ  replay");
    let mut report = BenchReport::new("big_little", "full");
    for (objective, placed, trace, live_nj, saved_ns) in &placements {
        let mut fresh: Box<dyn OffloadPolicy> = match objective.as_str() {
            "latency" => Box::<BlindOffloadPolicy>::default(),
            "energy" => Box::new(EnergyPolicy::new(cfg)),
            _ => Box::new(EdpPolicy::new(cfg)),
        };
        let (ms, mj) = assert_exact_replay(trace, fresh.as_mut());
        println!(
            "{objective:<11} {:<15} {ms:>11.1} {mj:>12.3}  exact",
            name(*placed)?
        );
        // A sequential hot loop has no latency distribution to speak
        // of: after settling every call costs the same, so the mean
        // call time stands in for both percentile columns.
        let call_ms = ms / ITERS as f64;
        report.push(
            BenchRow::new(objective)
                .metric("calls", Metric::Int(ITERS as u64))
                .metric("throughput_calls_per_s", Metric::Fixed(ITERS as f64 * 1e3 / ms, 1))
                .metric("p50_ms", Metric::Fixed(call_ms, 3))
                .metric("p99_ms", Metric::Fixed(call_ms, 3))
                .metric("saved_setup_ns", Metric::Int(*saved_ns))
                .metric("energy_nj", Metric::Int(*live_nj))
                .metric("availability", Metric::Fixed(1.0, 6))
                .metric("placement", Metric::Str(name(*placed)?))
                .metric("total_ms", Metric::Fixed(ms, 3))
                .metric("total_mj", Metric::Fixed(mj, 3))
                .metric("live_total_mj", Metric::Fixed(*live_nj as f64 / 1e6, 3))
                .metric("replay_exact", Metric::Bool(true)),
        );
    }

    // The headline divergence: minimizing time and minimizing joules
    // pick different silicon for the same call stream.
    let by = |o: &str| placements.iter().find(|(n, ..)| n == o).unwrap().1;
    assert_eq!(by("latency"), big, "latency must race to the big core");
    assert_eq!(by("energy"), little, "energy must settle on the little core");
    assert_ne!(
        by("latency"),
        by("energy"),
        "the two objectives must disagree on placement"
    );
    assert_eq!(by("edp"), big, "EDP weighs little's slowness over its frugality");

    // What-if: the latency-optimal recording re-priced under every
    // objective (counterfactual rows use the trace's power header).
    println!("\nwhat-if over the latency-optimal recording:");
    println!("{:<18} {:>12} {:>12} {:>9}", "policy", "total ms", "total mJ", "diverged");
    let latency_trace = &placements[0].2;
    let mut whatif: Vec<Box<dyn OffloadPolicy>> = vec![
        Box::<BlindOffloadPolicy>::default(),
        Box::new(EnergyPolicy::new(cfg)),
        Box::new(EdpPolicy::new(cfg)),
    ];
    for p in whatif.iter_mut() {
        let o = replay(latency_trace, p.as_mut());
        println!(
            "{:<18} {:>12.1} {:>12.3} {:>9}",
            o.policy,
            o.total_ms,
            o.total_energy_nj as f64 / 1e6,
            o.diverged()
        );
    }

    report.write(std::path::Path::new("BENCH_energy.json"))?;
    println!("\nwrote BENCH_energy.json");
    println!(
        "\nsame calls, three answers: latency -> {}, energy -> {}, EDP -> {}; every \
         recording replayed to its exact nanoseconds and nanojoules.",
        name(by("latency"))?,
        name(by("energy"))?,
        name(by("edp"))?
    );
    Ok(())
}
