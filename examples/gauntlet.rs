//! Scenario gauntlet smoke entry — the determinism-contract proof CI
//! runs on every push.
//!
//! Runs the full matrix at smoke scale three times:
//!
//! 1. under the default master seed — the artifact run, written to
//!    `BENCH_gauntlet.json`;
//! 2. under the same seed again — the serialized artifact must be
//!    **bit-identical** (the contract PR-over-PR diffing relies on);
//! 3. under a different master seed — the artifact must *differ*
//!    (bursty arrival schedules, and therefore timings, move).
//!
//! The artifact is then parsed back through the schema-validating
//! reader, closing the loop CI's trajectory table depends on.  Every
//! cell already asserted queue invariants, exactly-once resolution and
//! per-target energy conservation internally — a cell that cannot
//! prove its books simply errors the run.  Each run also sweeps the
//! threaded-ingest spur (real OS ingest threads against a pump
//! thread); those cells assert invariants only and contribute no
//! artifact rows, so the bit-identical contract is untouched.
//!
//! `cargo run --release --example gauntlet [-- --smoke]`

use vpe::bench_harness::{gauntlet, GauntletConfig, ParsedBench};

fn main() -> vpe::Result<()> {
    let args = vpe::util::cli::Args::parse(std::env::args().skip(1))?;
    // The example is CI's smoke entry: smoke scale is the default, and
    // the flag is accepted for symmetry with the other examples.
    let _ = args.flag("smoke");
    let calls: usize = args.opt("calls", 64)?;
    args.finish()?;

    let mut cfg = GauntletConfig::smoke();
    cfg.calls_per_cell = calls;
    let cells = cfg.cells().len();
    println!("== scenario gauntlet: {cells} cells x {calls} calls, seed {:#x} ==\n", cfg.seed);

    let first = gauntlet::run_with(&cfg, |row| {
        println!(
            "  {:<44} {:>8.1} calls/s  p99 {:>8.3} ms",
            row.cell(),
            row.f64("throughput_calls_per_s").unwrap_or(0.0),
            row.f64("p99_ms").unwrap_or(0.0)
        );
    })?;
    let text = first.write(std::path::Path::new("BENCH_gauntlet.json"))?;

    // Determinism contract, leg 1: same seed, bit-identical artifact.
    let rerun = gauntlet::run(&cfg)?.to_json_string()?;
    assert_eq!(text, rerun, "same-seed rerun must serialize bit-identically");

    // Leg 2: a different master seed must move the artifact.
    let mut other = cfg.clone();
    other.seed ^= 0x5EED;
    let moved = gauntlet::run(&other)?.to_json_string()?;
    assert_ne!(text, moved, "a different master seed must produce a different artifact");

    // Leg 3: the artifact roundtrips through the schema validator.
    let parsed = ParsedBench::parse(&text)?;
    assert_eq!(parsed.example, "gauntlet");
    assert_eq!(parsed.cells.len(), cells);
    assert!(parsed.cells.len() >= 24, "the matrix must sweep at least 24 cells");

    println!("\nwrote BENCH_gauntlet.json ({cells} rows)");
    println!(
        "determinism: same-seed rerun bit-identical; seed {:#x} diverges; schema validated.",
        other.seed
    );
    Ok(())
}
