//! Multi-tenant serving under load — the serving layer's acceptance
//! proof, in three legs.
//!
//! **Leg A (inline, deterministic).**  Eight tenants with skewed,
//! bursty call mixes (five functions from ~0.1 ms dot products to a
//! ~27 ms monster matmul) hammer one [`SchedulerCore`] driven inline,
//! wrapped around a coordinator with a single fast accelerator, two
//! slower helpers, and the calibrated DSP.  Every function's dispatch
//! slot pins to the fast unit, so all eight tenants contend for one
//! genuinely shared bottleneck — which makes the fairness assertion a
//! *scheduling* property (deficit round robin must equalize released
//! cost), not an accident of load placement.  The run sustains ~10⁵
//! calls (~10³ with `--smoke`) and asserts:
//!
//! - **zero queue-invariant violations**, swept every iteration:
//!   accepted population <= `max_inflight_total`, `submitted ==
//!   retired + in_flight`, every remote depth <= `max_queue_per_target`;
//! - **zero host bounces**: admission + saturation holdback replace the
//!   bounce path entirely;
//! - **fairness**: at the 25%-complete mark (every tenant still
//!   backlogged) no tenant's released-cost share sits below 1/2 of the
//!   mean share;
//! - **bounded tail**: pooled p99/p50 completion latency <= 50;
//! - every admitted call completes exactly once and resolves its
//!   [`Completion`] handle; oversized calls are preempted into shards.
//!
//! **Leg B (submit-path contention).**  Eight real OS threads submit
//! the same call storm two ways: serialized through one
//! `Arc<Mutex<SchedulerCore>>` (the pre-split architecture, every
//! submitter contending for the whole core) and through per-tenant
//! lock-free [`Ingress`] clones (atomic CAS admission + a private MPSC
//! push).  Wall-clock submission throughput is measured for both and
//! the lock-free path must sustain **>= 2x** the locked baseline
//! (asserted when the machine has >= 4 hardware threads; always
//! recorded in the artifact).
//!
//! **Leg C (threaded end-to-end).**  Over multiple seeds: a dedicated
//! pump thread ([`SchedulerCore::spawn_pump`]) drains while eight
//! ingest threads submit with retry-on-reject backoff.  Asserted per
//! seed: every admitted handle resolves (zero stranded), books balance
//! to empty, zero invariant violations from the pump's per-iteration
//! sweeps, no staging leaks.
//!
//! Emits `BENCH_serving.json` through the shared
//! [`vpe::bench_harness::report`] writer — one schema across every
//! trajectory artifact, diffable across PRs (CI uploads it per run).
//! Leg A's columns are deterministic; leg B contributes the wall-clock
//! `submit_throughput_calls_per_s` / `locked_submit_calls_per_s` /
//! `submit_speedup` columns.
//!
//! `cargo run --release --example serving_load [-- --smoke]`

use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Instant;

use vpe::bench_harness::{BenchReport, BenchRow, Metric};
use vpe::coordinator::policy::AlwaysOffloadPolicy;
use vpe::coordinator::serving::{AdmitOutcome, Completion, Ingress, SchedulerCore, TenantId};
use vpe::coordinator::{Vpe, VpeConfig};
use vpe::jit::module::FunctionId;
use vpe::platform::{TargetSpec, TransferModel, Transport};
use vpe::workloads::{PaperScale, WorkloadKind};

/// Tenants sharing the serving core (and ingest threads in legs B/C).
const TENANTS: usize = 8;
/// Retirements pumped per driver iteration in the inline leg.
const PUMP_BATCH: usize = 32;
/// Per-tenant mix weights over the function pool `[tiny, small, med,
/// big, monster]` — skewed on purpose: tenant 0 is interactive
/// small-call traffic, tenant 7 batches monsters.
const MIXES: [[u32; 5]; TENANTS] = [
    [6, 6, 2, 1, 0],
    [2, 6, 5, 2, 0],
    [1, 3, 8, 3, 0],
    [1, 2, 3, 8, 0],
    [3, 4, 4, 3, 1],
    [4, 5, 2, 2, 2],
    [2, 2, 5, 5, 1],
    [2, 2, 3, 4, 4],
];

/// Deterministic arrival randomness (no wall clock anywhere).
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0 >> 33
    }

    fn pick(&mut self, weights: &[u32; 5], pool: &[FunctionId; 5]) -> FunctionId {
        let total: u32 = weights.iter().sum();
        let mut r = (self.next() % total as u64) as u32;
        for (w, f) in weights.iter().zip(pool) {
            if r < *w {
                return *f;
            }
            r -= w;
        }
        pool[4]
    }
}

fn build_platform(tune: impl FnOnce(&mut VpeConfig)) -> vpe::Result<(Vpe, [FunctionId; 5])> {
    let mut cfg = VpeConfig::sim_only();
    cfg.tenant_quota = 32; // bound per-tenant backlog (and latency)
    cfg.max_inflight_total = 200; // < 8 * 32: saturation rejections occur
    cfg.deadline_ns = 20_000_000; // 20 ms: the monster must preempt
    tune(&mut cfg);
    let mut vpe = Vpe::with_policy(cfg, Box::new(AlwaysOffloadPolicy))?;

    // serve-a is strictly fastest at every workload — the shared
    // accelerator all dispatch slots pin to.  serve-b/-c only see work
    // through preemption fan-outs (and warm-up host calls aside, the
    // DSP likewise).
    let rates: [(&str, [f64; 4]); 3] = [
        ("serve-a", [1.0, 2.0, 2.2, 1.5]),
        ("serve-b", [1.6, 3.2, 3.0, 2.2]),
        ("serve-c", [2.0, 4.0, 3.6, 2.6]),
    ];
    let kinds =
        [WorkloadKind::Dotprod, WorkloadKind::Pattern, WorkloadKind::Conv2d, WorkloadKind::Matmul];
    for (name, per_kind) in rates {
        let id = vpe.soc_mut().add_target(TargetSpec::new(name, 1_200_000_000).with_transport(
            Transport::SharedMemory(TransferModel {
                dispatch_fixed_ns: 1_500_000,
                per_param_byte_ns: 1.0,
            }),
        ));
        for (kind, rate) in kinds.iter().zip(per_kind) {
            vpe.soc_mut().cost.set_rate(*kind, id, rate);
        }
    }

    // The function pool: predicted steady-state costs on serve-a of
    // ~1.6 / 2.1 / 3.7 / 4.7 / 26.7 ms.  Only the monster crosses the
    // 20 ms deadline.
    let tiny = vpe.register_workload(WorkloadKind::Dotprod)?;
    vpe.set_scale(tiny, PaperScale { items: 1e5, param_bytes: 48, payload_bytes: 4096 })?;
    let small = vpe.register_workload(WorkloadKind::Pattern)?;
    vpe.set_scale(small, PaperScale { items: 3e5, param_bytes: 48, payload_bytes: 4096 })?;
    let med = vpe.register_workload(WorkloadKind::Conv2d)?;
    vpe.set_scale(med, PaperScale { items: 1e6, param_bytes: 48, payload_bytes: 4096 })?;
    let big = vpe.register_matmul(128)?;
    let monster = vpe.register_matmul(256)?;

    let pool = [tiny, small, med, big, monster];
    // Warm-up: first call profiles on the host, the policy commits the
    // offload — serving-time cost predictions are steady-state.
    for f in pool {
        vpe.call(f)?;
    }
    let accel = vpe.soc().registry.iter().find(|(_, s)| s.name == "serve-a").unwrap().0;
    for f in pool {
        assert_eq!(vpe.current_target(f)?, accel, "warm-up must pin every slot to serve-a");
    }
    Ok((vpe, pool))
}

/// Leg B result: submissions/second through each front-end.
struct SubmitBench {
    locked_rate: f64,
    lockfree_rate: f64,
    speedup: f64,
    parallelism: usize,
}

/// Leg B: measure pure submit-path throughput under 8-thread
/// contention — the same storm serialized through one
/// `Arc<Mutex<SchedulerCore>>` versus fanned through lock-free
/// [`Ingress`] clones.  Admission bounds are widened so every
/// submission is *admitted*: the measure is the cost of a successful
/// submit (reserve, stamp, enqueue), not of bouncing off a full
/// server, and both paths run the identical workload.
fn submit_throughput_leg(smoke: bool) -> vpe::Result<SubmitBench> {
    let per_thread: usize = if smoke { 1_000 } else { 5_000 };
    let tune = move |c: &mut VpeConfig| {
        c.tenant_quota = per_thread + 8;
        c.max_inflight_total = TENANTS * per_thread + 8;
        c.ingest_queue_depth = per_thread + 8;
        c.deadline_ns = 0; // pure submit-path measurement: no preemption
    };
    let drain_and_check = |core: &mut SchedulerCore, handles: &[Completion]| -> vpe::Result<()> {
        core.drive_inline()?;
        assert!(core.is_idle(), "drain left the books non-empty");
        assert_eq!(core.accepted_inflight(), 0);
        assert_eq!(core.invariant_violations(), 0);
        assert!(handles.iter().all(Completion::is_done), "stranded completion after drain");
        assert_eq!(
            handles.len() as u64 + core.rejected(),
            (TENANTS * per_thread) as u64,
            "every submission either admitted or rejected"
        );
        Ok(())
    };

    // Locked baseline: the pre-split architecture — every submitter
    // serializes on one mutex around the whole core.
    let (vpe, pool) = build_platform(tune)?;
    let mut core = SchedulerCore::new(vpe);
    core.vpe_mut().limit_events(50_000);
    let f = pool[0];
    let locked = Arc::new(Mutex::new(core));
    let t0 = Instant::now();
    let workers: Vec<_> = (0..TENANTS)
        .map(|t| {
            let locked = Arc::clone(&locked);
            thread::spawn(move || {
                let mut handles = Vec::new();
                for _ in 0..per_thread {
                    let outcome = locked
                        .lock()
                        .expect("core mutex poisoned")
                        .try_submit(TenantId(t as u32), f)
                        .expect("submit never errors on a bound function");
                    if let AdmitOutcome::Admitted(done) = outcome {
                        handles.push(done);
                    }
                }
                handles
            })
        })
        .collect();
    let mut admitted = Vec::new();
    for w in workers {
        admitted.extend(w.join().expect("locked submitter panicked"));
    }
    let locked_elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    let mut core = match Arc::try_unwrap(locked) {
        Ok(m) => m.into_inner().expect("core mutex poisoned"),
        Err(_) => unreachable!("all submitters joined"),
    };
    drain_and_check(&mut core, &admitted)?;

    // Lock-free ingress: same platform, same storm, no lock anywhere
    // on the submit path.
    let (vpe, pool) = build_platform(tune)?;
    let mut core = SchedulerCore::new(vpe);
    core.vpe_mut().limit_events(50_000);
    let f = pool[0];
    let ingresses: Vec<Ingress> = (0..TENANTS).map(|t| core.ingress(TenantId(t as u32))).collect();
    let t0 = Instant::now();
    let workers: Vec<_> = ingresses
        .into_iter()
        .map(|ing| {
            thread::spawn(move || {
                let mut handles = Vec::new();
                for _ in 0..per_thread {
                    let outcome =
                        ing.try_submit(f).expect("submit never errors on a bound function");
                    if let AdmitOutcome::Admitted(done) = outcome {
                        handles.push(done);
                    }
                }
                handles
            })
        })
        .collect();
    let mut admitted = Vec::new();
    for w in workers {
        admitted.extend(w.join().expect("ingress submitter panicked"));
    }
    let lockfree_elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    drain_and_check(&mut core, &admitted)?;

    let total = (TENANTS * per_thread) as f64;
    let locked_rate = total / locked_elapsed;
    let lockfree_rate = total / lockfree_elapsed;
    Ok(SubmitBench {
        locked_rate,
        lockfree_rate,
        speedup: lockfree_rate / locked_rate.max(1e-9),
        parallelism: thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    })
}

/// Leg C: full threaded serving — a pump thread drains while eight
/// ingest threads submit with retry-on-reject backoff — repeated over
/// several seeds.  The threaded path promises exactly-once completion
/// and balanced books (not a fixed interleaving), and that is exactly
/// what gets asserted.
fn threaded_serving_leg(smoke: bool) -> vpe::Result<(usize, usize)> {
    let seeds: &[u64] =
        if smoke { &[0xA11CE, 0x0B0B5] } else { &[0xA11CE, 0x0B0B5, 0xC0FFEE] };
    let per_tenant: usize = if smoke { 48 } else { 256 };
    for &seed in seeds {
        let (vpe, pool) = build_platform(|_| {})?;
        let mut core = SchedulerCore::new(vpe);
        core.vpe_mut().limit_events(50_000);
        let ingresses: Vec<Ingress> =
            (0..TENANTS).map(|t| core.ingress(TenantId(t as u32))).collect();
        let pump = core.spawn_pump();
        let workers: Vec<_> = ingresses
            .into_iter()
            .enumerate()
            .map(|(t, ing)| {
                thread::spawn(move || {
                    let mut rng = Lcg(seed ^ (0x9e37 + t as u64));
                    let mut handles = Vec::with_capacity(per_tenant);
                    let mut rejections = 0u64;
                    while handles.len() < per_tenant {
                        let f = rng.pick(&MIXES[t], &pool);
                        match ing.try_submit(f).expect("submit never errors on a bound function")
                        {
                            AdmitOutcome::Admitted(done) => handles.push(done),
                            AdmitOutcome::Rejected { .. } => {
                                rejections += 1;
                                assert!(rejections < 50_000_000, "tenant {t} starved");
                                thread::yield_now();
                            }
                        }
                    }
                    handles
                })
            })
            .collect();
        let mut handles = Vec::new();
        for w in workers {
            handles.extend(w.join().expect("ingest worker panicked"));
        }
        // Read the pump's running sweep before shutdown consumes it.
        let swept = pump.invariant_violations();
        let core = pump.shutdown()?;
        let total = TENANTS * per_tenant;
        assert_eq!(handles.len(), total);
        assert!(handles.iter().all(Completion::is_done), "stranded completion after shutdown");
        assert_eq!(swept, 0, "pump sweeps saw an invariant violation");
        assert_eq!(core.invariant_violations(), 0);
        assert!(core.is_idle(), "shutdown left the books non-empty");
        assert_eq!(core.accepted_inflight(), 0);
        assert_eq!(core.vpe().in_flight(), 0);
        assert_eq!(core.vpe().soc().shared.used_bytes(), 0, "no staging leaks");
        for s in core.vpe().serving_stats() {
            assert_eq!(s.submitted, per_tenant as u64, "tenant {} admitted exactly", s.tenant.0);
            assert_eq!(s.completed, s.submitted, "tenant {} completed exactly", s.tenant.0);
            assert_eq!(s.failed, 0);
        }
    }
    Ok((seeds.len(), seeds.len() * TENANTS * per_tenant))
}

fn main() -> vpe::Result<()> {
    let args = vpe::util::cli::Args::parse(std::env::args().skip(1))?;
    let smoke = args.flag("smoke");
    let total: usize = args.opt("calls", if smoke { 1_000 } else { 100_000 })?;
    args.finish()?;
    let per_tenant = total / TENANTS;
    let total = per_tenant * TENANTS;

    println!("== multi-tenant serving: {total} calls, {TENANTS} tenants, skewed bursty mixes ==");
    println!("   (one shared accelerator; DRR fairness, admission control, 20 ms deadline)\n");

    let (vpe, pool) = build_platform(|_| {})?;
    let quota = vpe.config().tenant_quota;
    let max_total = vpe.config().max_inflight_total;
    let mut core = SchedulerCore::new(vpe);
    core.vpe_mut().limit_events(50_000);
    let t0 = core.vpe().clock().now_ns();

    let mut rng = Lcg(0x5e41);
    let mut remaining = [per_tenant; TENANTS];
    let mut admitted = [0usize; TENANTS];
    let mut completed = [0usize; TENANTS];
    let mut backoff_until = [0u64; TENANTS];
    let mut handles: Vec<Completion> = Vec::with_capacity(total);
    let mut violations = 0usize;
    let mut max_accepted = 0usize;
    let mut snapshot: Option<Vec<u64>> = None;
    let mut guard = 0usize;

    loop {
        guard += 1;
        assert!(guard < total * 60 + 10_000, "driver loop failed to make progress");

        // Bursty arrivals: a tenant whose pending population fell below
        // half its quota refills to quota in one burst, backing off
        // when admission control says so.
        let now = core.vpe().clock().now_ns();
        for t in 0..TENANTS {
            if remaining[t] == 0 || now < backoff_until[t] {
                continue;
            }
            let pending = admitted[t] - completed[t];
            if pending >= quota / 2 {
                continue;
            }
            let mut burst = (quota - pending).min(remaining[t]);
            while burst > 0 {
                let f = rng.pick(&MIXES[t], &pool);
                match core.try_submit(TenantId(t as u32), f)? {
                    AdmitOutcome::Admitted(done) => {
                        handles.push(done);
                        admitted[t] += 1;
                        remaining[t] -= 1;
                        burst -= 1;
                    }
                    AdmitOutcome::Rejected { retry_after_ns, .. } => {
                        backoff_until[t] =
                            core.vpe().clock().now_ns().saturating_add(retry_after_ns);
                        break;
                    }
                }
            }
        }

        // Drive a batch of retirements.
        let mut progressed = false;
        for _ in 0..PUMP_BATCH {
            match core.pump()? {
                Some(rec) => {
                    progressed = true;
                    if let Some(TenantId(t)) = rec.tenant {
                        completed[t as usize] += 1;
                    }
                }
                None => break,
            }
        }

        // Invariant sweep, every iteration (population bound, dispatch
        // accounting, per-target depth — the same sweep the gauntlet
        // runs on its clean cells).
        violations += core.invariant_violations();
        max_accepted = max_accepted.max(core.accepted_inflight());

        let done_total: usize = completed.iter().sum();
        if snapshot.is_none() && done_total >= total / 4 {
            snapshot = Some((0..TENANTS).map(|t| core.served_ns(TenantId(t as u32))).collect());
        }
        if remaining.iter().all(|&r| r == 0) && core.is_idle() {
            break;
        }
        if !progressed {
            // Nothing retirable and every eligible tenant backed off:
            // advance the sim clock to the earliest retry.
            let next = (0..TENANTS)
                .filter(|&t| remaining[t] > 0)
                .map(|t| backoff_until[t])
                .min();
            if let Some(at) = next {
                core.idle_until(at);
            }
        }
    }

    let elapsed_ns = core.vpe().clock().now_ns() - t0;
    let elapsed_s = elapsed_ns as f64 / 1e9;
    let throughput = total as f64 / elapsed_s;
    let (p50_ns, p99_ns) =
        core.vpe().serving_latency_percentiles().expect("completions recorded");
    let tail_ratio = p99_ns as f64 / p50_ns.max(1) as f64;
    let snap = snapshot.expect("the run crossed the 25% mark");
    let mean_served = snap.iter().sum::<u64>() as f64 / TENANTS as f64;
    let min_share_frac = *snap.iter().min().unwrap() as f64 / mean_served;

    println!("tenant  submitted  completed  rejected   p50 ms   p99 ms  released ms");
    for s in core.vpe().serving_stats() {
        println!(
            "{:>6}  {:>9}  {:>9}  {:>8}  {:>7.1}  {:>7.1}  {:>11.1}",
            format!("t{}", s.tenant.0),
            s.submitted,
            s.completed,
            s.rejected,
            s.p50_latency_ns as f64 / 1e6,
            s.p99_latency_ns as f64 / 1e6,
            core.served_ns(s.tenant) as f64 / 1e6,
        );
    }
    println!();
    println!("sim time: {elapsed_s:.2} s   throughput: {throughput:.1} calls/s");
    println!(
        "pooled latency: p50 {:.1} ms, p99 {:.1} ms (ratio {tail_ratio:.1})",
        p50_ns as f64 / 1e6,
        p99_ns as f64 / 1e6
    );
    println!(
        "admission: {} rejected, max accepted in flight {max_accepted}/{max_total}",
        core.rejected()
    );
    println!(
        "preemption: {} monster calls sharded; batching saved {:.1} ms of setup",
        core.preempted(),
        core.vpe().saved_setup_ns() as f64 / 1e6
    );
    println!("fairness at 25% complete: min released share = {min_share_frac:.2}x mean");

    // The accelerator's utilization over the run (occupied / elapsed).
    let accel = core.vpe().soc().registry.iter().find(|(_, s)| s.name == "serve-a").unwrap().0;
    let utilization = core.vpe().scheduler().occupied_ns(accel) as f64 / elapsed_ns as f64;
    println!("accelerator utilization: {:.0}%", utilization * 100.0);

    // -- acceptance (leg A) --------------------------------------------------
    let completed_total: usize = completed.iter().sum();
    assert_eq!(completed_total, total, "every admitted call completes");
    assert_eq!(handles.len(), total);
    assert!(handles.iter().all(|h| h.is_done()), "every handle resolved");
    for (t, done) in completed.iter().enumerate() {
        assert_eq!(*done, per_tenant, "tenant {t} finished its budget");
    }
    assert_eq!(violations, 0, "queue invariants held throughout");
    assert_eq!(core.vpe().scheduler().bounce_count(), 0, "holdback replaces the host bounce");
    assert_eq!(core.accepted_inflight(), 0);
    assert_eq!(core.vpe().in_flight(), 0);
    assert_eq!(core.vpe().soc().shared.used_bytes(), 0, "no staging leaks");
    assert!(core.rejected() > 0, "admission control must engage at this load");
    assert!(core.preempted() > 0, "the monster must preempt into shards");
    assert!(
        min_share_frac >= 0.5,
        "no tenant below half its fair share (got {min_share_frac:.2})"
    );
    assert!(tail_ratio <= 50.0, "p99/p50 must stay bounded (got {tail_ratio:.1})");

    // -- leg B: submit-path contention ---------------------------------------
    println!("\n== submit path: {TENANTS} threads, locked core vs lock-free ingress ==");
    let bench = submit_throughput_leg(smoke)?;
    println!(
        "locked   {:>12.0} submits/s   (one mutex around the whole core)",
        bench.locked_rate
    );
    println!(
        "ingress  {:>12.0} submits/s   (CAS admission + per-tenant MPSC)",
        bench.lockfree_rate
    );
    println!(
        "speedup  {:>11.2}x            ({} hardware threads)",
        bench.speedup, bench.parallelism
    );
    if bench.parallelism >= 4 {
        assert!(
            bench.speedup >= 2.0,
            "lock-free ingress must sustain >= 2x the locked submit throughput \
             (got {:.2}x on {} hardware threads)",
            bench.speedup,
            bench.parallelism
        );
    } else {
        println!("         (speedup assertion skipped: < 4 hardware threads)");
    }

    // -- leg C: threaded end-to-end ------------------------------------------
    println!("\n== threaded serving: pump thread + {TENANTS} ingest threads ==");
    let (seeds, threaded_calls) = threaded_serving_leg(smoke)?;
    println!(
        "{threaded_calls} calls over {seeds} seeds: zero stranded handles, \
         balanced books, zero invariant violations"
    );

    let mut report = BenchReport::new("serving_load", if smoke { "smoke" } else { "full" });
    report.push(
        BenchRow::new("all")
            .metric("calls", Metric::Int(total as u64))
            .metric("throughput_calls_per_s", Metric::Fixed(throughput, 1))
            .metric("p50_ms", Metric::Fixed(p50_ns as f64 / 1e6, 3))
            .metric("p99_ms", Metric::Fixed(p99_ns as f64 / 1e6, 3))
            .metric("saved_setup_ns", Metric::Int(core.vpe().saved_setup_ns()))
            .metric("energy_nj", Metric::Int(core.vpe().total_energy_nj()))
            .metric("availability", Metric::Fixed(core.vpe().availability().unwrap_or(1.0), 6))
            .metric("tenants", Metric::Int(TENANTS as u64))
            .metric("sim_seconds", Metric::Fixed(elapsed_s, 3))
            .metric("p99_over_p50", Metric::Fixed(tail_ratio, 2))
            .metric("rejected", Metric::Int(core.rejected()))
            .metric("preempted", Metric::Int(core.preempted()))
            .metric("bounced", Metric::Int(core.vpe().scheduler().bounce_count()))
            .metric("batches_formed", Metric::Int(core.vpe().batches_formed()))
            .metric("max_accepted_inflight", Metric::Int(max_accepted as u64))
            .metric("accel_utilization", Metric::Fixed(utilization, 3))
            .metric("min_share_frac", Metric::Fixed(min_share_frac, 3))
            .metric("violations", Metric::Int(violations as u64))
            .metric("submit_throughput_calls_per_s", Metric::Fixed(bench.lockfree_rate, 1))
            .metric("locked_submit_calls_per_s", Metric::Fixed(bench.locked_rate, 1))
            .metric("submit_speedup", Metric::Fixed(bench.speedup, 2))
            .metric("threaded_calls", Metric::Int(threaded_calls as u64)),
    );
    report.write(std::path::Path::new("BENCH_serving.json"))?;
    println!("\nwrote BENCH_serving.json");
    println!(
        "\n{} inline calls from {TENANTS} tenants: fair to within {:.0}% of an equal split, \
         {} oversized calls preempted, {} rejected with retry hints, zero bounces, \
         zero invariant violations; lock-free ingress sustained {:.2}x the locked \
         submit throughput across {TENANTS} threads.",
        total,
        (1.0 - min_share_frac) * 100.0,
        core.preempted(),
        core.rejected(),
        bench.speedup
    );
    Ok(())
}
