//! Multi-tenant serving under load — the serving layer's acceptance
//! proof.
//!
//! Eight tenants with skewed, bursty call mixes (five functions from
//! ~0.1 ms dot products to a ~27 ms monster matmul) hammer one
//! [`Server`] wrapped around a coordinator with a single fast
//! accelerator, two slower helpers, and the calibrated DSP.  Every
//! function's dispatch slot pins to the fast unit, so all eight
//! tenants contend for one genuinely shared bottleneck — which makes
//! the fairness assertion a *scheduling* property (deficit round robin
//! must equalize released cost), not an accident of load placement.
//!
//! The run sustains ~10⁵ calls (~10³ with `--smoke`) and asserts:
//!
//! - **zero queue-invariant violations**, swept every iteration:
//!   accepted population <= `max_inflight_total`, `submitted ==
//!   retired + in_flight`, every remote depth <= `max_queue_per_target`;
//! - **zero host bounces**: admission + saturation holdback replace the
//!   bounce path entirely;
//! - **fairness**: at the 25%-complete mark (every tenant still
//!   backlogged) no tenant's released-cost share sits below 1/2 of the
//!   mean share;
//! - **bounded tail**: pooled p99/p50 completion latency <= 50;
//! - every admitted call completes exactly once and resolves its
//!   [`Completion`] handle; oversized calls are preempted into shards.
//!
//! Emits `BENCH_serving.json` through the shared
//! [`vpe::bench_harness::report`] writer — one schema across every
//! trajectory artifact, diffable across PRs (CI uploads it per run).
//!
//! `cargo run --release --example serving_load [-- --smoke]`

use vpe::bench_harness::{BenchReport, BenchRow, Metric};
use vpe::coordinator::policy::AlwaysOffloadPolicy;
use vpe::coordinator::serving::{AdmitOutcome, Completion, Server, TenantId};
use vpe::coordinator::{Vpe, VpeConfig};
use vpe::jit::module::FunctionId;
use vpe::platform::{TargetSpec, TransferModel, Transport};
use vpe::workloads::{PaperScale, WorkloadKind};

/// Tenants sharing the server.
const TENANTS: usize = 8;
/// Retirements pumped per driver iteration.
const PUMP_BATCH: usize = 32;
/// Per-tenant mix weights over the function pool `[tiny, small, med,
/// big, monster]` — skewed on purpose: tenant 0 is interactive
/// small-call traffic, tenant 7 batches monsters.
const MIXES: [[u32; 5]; TENANTS] = [
    [6, 6, 2, 1, 0],
    [2, 6, 5, 2, 0],
    [1, 3, 8, 3, 0],
    [1, 2, 3, 8, 0],
    [3, 4, 4, 3, 1],
    [4, 5, 2, 2, 2],
    [2, 2, 5, 5, 1],
    [2, 2, 3, 4, 4],
];

/// Deterministic arrival randomness (no wall clock anywhere).
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0 >> 33
    }

    fn pick(&mut self, weights: &[u32; 5], pool: &[FunctionId; 5]) -> FunctionId {
        let total: u32 = weights.iter().sum();
        let mut r = (self.next() % total as u64) as u32;
        for (w, f) in weights.iter().zip(pool) {
            if r < *w {
                return *f;
            }
            r -= w;
        }
        pool[4]
    }
}

fn build_platform() -> vpe::Result<(Vpe, [FunctionId; 5])> {
    let mut cfg = VpeConfig::sim_only();
    cfg.tenant_quota = 32; // bound per-tenant backlog (and latency)
    cfg.max_inflight_total = 200; // < 8 * 32: saturation rejections occur
    cfg.deadline_ns = 20_000_000; // 20 ms: the monster must preempt
    let mut vpe = Vpe::with_policy(cfg, Box::new(AlwaysOffloadPolicy))?;

    // serve-a is strictly fastest at every workload — the shared
    // accelerator all dispatch slots pin to.  serve-b/-c only see work
    // through preemption fan-outs (and warm-up host calls aside, the
    // DSP likewise).
    let rates: [(&str, [f64; 4]); 3] = [
        ("serve-a", [1.0, 2.0, 2.2, 1.5]),
        ("serve-b", [1.6, 3.2, 3.0, 2.2]),
        ("serve-c", [2.0, 4.0, 3.6, 2.6]),
    ];
    let kinds =
        [WorkloadKind::Dotprod, WorkloadKind::Pattern, WorkloadKind::Conv2d, WorkloadKind::Matmul];
    for (name, per_kind) in rates {
        let id = vpe.soc_mut().add_target(TargetSpec::new(name, 1_200_000_000).with_transport(
            Transport::SharedMemory(TransferModel {
                dispatch_fixed_ns: 1_500_000,
                per_param_byte_ns: 1.0,
            }),
        ));
        for (kind, rate) in kinds.iter().zip(per_kind) {
            vpe.soc_mut().cost.set_rate(*kind, id, rate);
        }
    }

    // The function pool: predicted steady-state costs on serve-a of
    // ~1.6 / 2.1 / 3.7 / 4.7 / 26.7 ms.  Only the monster crosses the
    // 20 ms deadline.
    let tiny = vpe.register_workload(WorkloadKind::Dotprod)?;
    vpe.set_scale(tiny, PaperScale { items: 1e5, param_bytes: 48, payload_bytes: 4096 })?;
    let small = vpe.register_workload(WorkloadKind::Pattern)?;
    vpe.set_scale(small, PaperScale { items: 3e5, param_bytes: 48, payload_bytes: 4096 })?;
    let med = vpe.register_workload(WorkloadKind::Conv2d)?;
    vpe.set_scale(med, PaperScale { items: 1e6, param_bytes: 48, payload_bytes: 4096 })?;
    let big = vpe.register_matmul(128)?;
    let monster = vpe.register_matmul(256)?;

    let pool = [tiny, small, med, big, monster];
    // Warm-up: first call profiles on the host, the policy commits the
    // offload — serving-time cost predictions are steady-state.
    for f in pool {
        vpe.call(f)?;
    }
    let accel = vpe.soc().registry.iter().find(|(_, s)| s.name == "serve-a").unwrap().0;
    for f in pool {
        assert_eq!(vpe.current_target(f)?, accel, "warm-up must pin every slot to serve-a");
    }
    Ok((vpe, pool))
}

fn main() -> vpe::Result<()> {
    let args = vpe::util::cli::Args::parse(std::env::args().skip(1))?;
    let smoke = args.flag("smoke");
    let total: usize = args.opt("calls", if smoke { 1_000 } else { 100_000 })?;
    args.finish()?;
    let per_tenant = total / TENANTS;
    let total = per_tenant * TENANTS;

    println!("== multi-tenant serving: {total} calls, {TENANTS} tenants, skewed bursty mixes ==");
    println!("   (one shared accelerator; DRR fairness, admission control, 20 ms deadline)\n");

    let (vpe, pool) = build_platform()?;
    let quota = vpe.config().tenant_quota;
    let max_total = vpe.config().max_inflight_total;
    let mut server = Server::new(vpe);
    server.vpe_mut().limit_events(50_000);
    let t0 = server.vpe().clock().now_ns();

    let mut rng = Lcg(0x5e41);
    let mut remaining = [per_tenant; TENANTS];
    let mut admitted = [0usize; TENANTS];
    let mut completed = [0usize; TENANTS];
    let mut backoff_until = [0u64; TENANTS];
    let mut handles: Vec<Completion> = Vec::with_capacity(total);
    let mut violations = 0usize;
    let mut max_accepted = 0usize;
    let mut snapshot: Option<Vec<u64>> = None;
    let mut guard = 0usize;

    loop {
        guard += 1;
        assert!(guard < total * 60 + 10_000, "driver loop failed to make progress");

        // Bursty arrivals: a tenant whose pending population fell below
        // half its quota refills to quota in one burst, backing off
        // when admission control says so.
        let now = server.vpe().clock().now_ns();
        for t in 0..TENANTS {
            if remaining[t] == 0 || now < backoff_until[t] {
                continue;
            }
            let pending = admitted[t] - completed[t];
            if pending >= quota / 2 {
                continue;
            }
            let mut burst = (quota - pending).min(remaining[t]);
            while burst > 0 {
                let f = rng.pick(&MIXES[t], &pool);
                match server.try_submit(TenantId(t as u32), f)? {
                    AdmitOutcome::Admitted(done) => {
                        handles.push(done);
                        admitted[t] += 1;
                        remaining[t] -= 1;
                        burst -= 1;
                    }
                    AdmitOutcome::Rejected { retry_after_ns, .. } => {
                        backoff_until[t] =
                            server.vpe().clock().now_ns().saturating_add(retry_after_ns);
                        break;
                    }
                }
            }
        }

        // Drive a batch of retirements.
        let mut progressed = false;
        for _ in 0..PUMP_BATCH {
            match server.pump()? {
                Some(rec) => {
                    progressed = true;
                    if let Some(TenantId(t)) = rec.tenant {
                        completed[t as usize] += 1;
                    }
                }
                None => break,
            }
        }

        // Invariant sweep, every iteration (population bound, dispatch
        // accounting, per-target depth — the same sweep the gauntlet
        // runs on its clean cells).
        violations += server.invariant_violations();
        max_accepted = max_accepted.max(server.accepted_inflight());

        let done_total: usize = completed.iter().sum();
        if snapshot.is_none() && done_total >= total / 4 {
            snapshot =
                Some((0..TENANTS).map(|t| server.served_ns(TenantId(t as u32))).collect());
        }
        if remaining.iter().all(|&r| r == 0) && server.is_idle() {
            break;
        }
        if !progressed {
            // Nothing retirable and every eligible tenant backed off:
            // advance the sim clock to the earliest retry.
            let next = (0..TENANTS)
                .filter(|&t| remaining[t] > 0)
                .map(|t| backoff_until[t])
                .min();
            if let Some(at) = next {
                server.idle_until(at);
            }
        }
    }

    let elapsed_ns = server.vpe().clock().now_ns() - t0;
    let elapsed_s = elapsed_ns as f64 / 1e9;
    let throughput = total as f64 / elapsed_s;
    let (p50_ns, p99_ns) =
        server.vpe().serving_latency_percentiles().expect("completions recorded");
    let tail_ratio = p99_ns as f64 / p50_ns.max(1) as f64;
    let snap = snapshot.expect("the run crossed the 25% mark");
    let mean_served = snap.iter().sum::<u64>() as f64 / TENANTS as f64;
    let min_share_frac = *snap.iter().min().unwrap() as f64 / mean_served;

    println!("tenant  submitted  completed  rejected   p50 ms   p99 ms  released ms");
    for s in server.vpe().serving_stats() {
        println!(
            "{:>6}  {:>9}  {:>9}  {:>8}  {:>7.1}  {:>7.1}  {:>11.1}",
            format!("t{}", s.tenant.0),
            s.submitted,
            s.completed,
            s.rejected,
            s.p50_latency_ns as f64 / 1e6,
            s.p99_latency_ns as f64 / 1e6,
            server.served_ns(s.tenant) as f64 / 1e6,
        );
    }
    println!();
    println!("sim time: {elapsed_s:.2} s   throughput: {throughput:.1} calls/s");
    println!(
        "pooled latency: p50 {:.1} ms, p99 {:.1} ms (ratio {tail_ratio:.1})",
        p50_ns as f64 / 1e6,
        p99_ns as f64 / 1e6
    );
    println!(
        "admission: {} rejected, max accepted in flight {max_accepted}/{max_total}",
        server.rejected()
    );
    println!(
        "preemption: {} monster calls sharded; batching saved {:.1} ms of setup",
        server.preempted(),
        server.vpe().saved_setup_ns() as f64 / 1e6
    );
    println!("fairness at 25% complete: min released share = {min_share_frac:.2}x mean");

    // The accelerator's utilization over the run (occupied / elapsed).
    let accel =
        server.vpe().soc().registry.iter().find(|(_, s)| s.name == "serve-a").unwrap().0;
    let utilization = server.vpe().scheduler().occupied_ns(accel) as f64 / elapsed_ns as f64;
    println!("accelerator utilization: {:.0}%", utilization * 100.0);

    // -- acceptance ---------------------------------------------------------
    let completed_total: usize = completed.iter().sum();
    assert_eq!(completed_total, total, "every admitted call completes");
    assert_eq!(handles.len(), total);
    assert!(handles.iter().all(|h| h.is_done()), "every handle resolved");
    for (t, done) in completed.iter().enumerate() {
        assert_eq!(*done, per_tenant, "tenant {t} finished its budget");
    }
    assert_eq!(violations, 0, "queue invariants held throughout");
    assert_eq!(server.vpe().scheduler().bounce_count(), 0, "holdback replaces the host bounce");
    assert_eq!(server.accepted_inflight(), 0);
    assert_eq!(server.vpe().in_flight(), 0);
    assert_eq!(server.vpe().soc().shared.used_bytes(), 0, "no staging leaks");
    assert!(server.rejected() > 0, "admission control must engage at this load");
    assert!(server.preempted() > 0, "the monster must preempt into shards");
    assert!(
        min_share_frac >= 0.5,
        "no tenant below half its fair share (got {min_share_frac:.2})"
    );
    assert!(tail_ratio <= 50.0, "p99/p50 must stay bounded (got {tail_ratio:.1})");

    let mut report = BenchReport::new("serving_load", if smoke { "smoke" } else { "full" });
    report.push(
        BenchRow::new("all")
            .metric("calls", Metric::Int(total as u64))
            .metric("throughput_calls_per_s", Metric::Fixed(throughput, 1))
            .metric("p50_ms", Metric::Fixed(p50_ns as f64 / 1e6, 3))
            .metric("p99_ms", Metric::Fixed(p99_ns as f64 / 1e6, 3))
            .metric("saved_setup_ns", Metric::Int(server.vpe().saved_setup_ns()))
            .metric("energy_nj", Metric::Int(server.vpe().total_energy_nj()))
            .metric("availability", Metric::Fixed(server.vpe().availability().unwrap_or(1.0), 6))
            .metric("tenants", Metric::Int(TENANTS as u64))
            .metric("sim_seconds", Metric::Fixed(elapsed_s, 3))
            .metric("p99_over_p50", Metric::Fixed(tail_ratio, 2))
            .metric("rejected", Metric::Int(server.rejected()))
            .metric("preempted", Metric::Int(server.preempted()))
            .metric("bounced", Metric::Int(server.vpe().scheduler().bounce_count()))
            .metric("batches_formed", Metric::Int(server.vpe().batches_formed()))
            .metric("max_accepted_inflight", Metric::Int(max_accepted as u64))
            .metric("accel_utilization", Metric::Fixed(utilization, 3))
            .metric("min_share_frac", Metric::Fixed(min_share_frac, 3))
            .metric("violations", Metric::Int(violations as u64)),
    );
    report.write(std::path::Path::new("BENCH_serving.json"))?;
    println!("\nwrote BENCH_serving.json");
    println!(
        "\n{} calls from {TENANTS} tenants: fair to within {:.0}% of an equal split, \
         {} oversized calls preempted, {} rejected with retry hints, zero bounces, \
         zero invariant violations.",
        total,
        (1.0 - min_share_frac) * 100.0,
        server.preempted(),
        server.rejected()
    );
    Ok(())
}
