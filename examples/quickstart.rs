//! Quickstart: the paper's promise in 30 lines.
//!
//! You write a hot function as if it ran on a plain CPU; VPE profiles
//! it, notices it is hot, moves it to the DSP, and your loop gets faster
//! — no code changes, no toolchain knowledge.
//!
//! Run with `cargo run --release --example quickstart`.  Real numerics
//! come from the pure-Rust reference backend by default (PJRT artifact
//! execution is opt-in via `--features pjrt` + `python/compile`); the
//! example falls back to simulation-only if construction fails.

use vpe::coordinator::{Vpe, VpeConfig};
use vpe::platform::dm3730;
use vpe::workloads::WorkloadKind;

fn main() -> vpe::Result<()> {
    // Build the coordinator: prefer real PJRT execution, fall back to
    // simulation-only when `make artifacts` has not been run.
    let mut vpe = match Vpe::new(VpeConfig::default()) {
        Ok(v) => v,
        Err(_) => {
            eprintln!("(artifacts missing — running simulation-only)");
            Vpe::new(VpeConfig::sim_only())?
        }
    };

    // "The developer just writes the code as if it had to be executed
    // on a standard CPU" — register the matrix-multiply hot loop.
    let matmul = vpe.register_workload(WorkloadKind::Matmul)?;

    // Call it in a loop.  VPE does the rest.
    for i in 0..25 {
        let rec = vpe.call(matmul)?;
        if i % 5 == 0 || rec.action.is_some() {
            println!(
                "iter {i:>2}: ran on {:<14} sim {:>7.1} ms{}{}",
                vpe.target_name(rec.target),
                rec.exec_ns as f64 / 1e6,
                rec.wall
                    .map(|w| format!("  (real PJRT {:.2} ms)", w.as_secs_f64() * 1e3))
                    .unwrap_or_default(),
                rec.action.map(|a| format!("  <- {a:?}")).unwrap_or_default(),
            );
        }
    }

    println!("\n{}", vpe.report());
    assert_eq!(vpe.current_target(matmul)?, dm3730::DSP);
    println!("matmul now runs on the DSP — transparently.");
    Ok(())
}
