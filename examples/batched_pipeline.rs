//! Batched remote dispatch, end to end — the tentpole's acceptance demo.
//!
//! Fig 2b's lesson is that a remote dispatch is dominated by a fixed
//! ~100 ms transport setup, which is why only long calls used to be
//! worth offloading.  This example streams many *medium-scale* calls
//! (128x128 matmuls, ~7 ms of DSP compute each) at a message-passing
//! SoC — the worst case for that setup cost — twice:
//!
//! 1. **unbatched** (`max_batch_width = 1`): every queued dispatch pays
//!    the full setup + round trip;
//! 2. **batched** (`max_batch_width = 8`): a wave of queued same-target
//!    submits coalesces into one `DispatchBatch` that pays the setup
//!    once, while wire/serde costs stay per call.
//!
//! Identical call streams, identical platform, identical policy — the
//! only variable is coalescing.  The example asserts the batched queue
//! sustains >= 3x the steady-state throughput of the unbatched one
//! (run in CI), and that the amortization bookkeeping is exact:
//! every wave saves exactly `(width - 1) * setup`.
//!
//! `cargo run --release --example batched_pipeline`

use vpe::coordinator::policy::AlwaysOffloadPolicy;
use vpe::coordinator::{Vpe, VpeConfig};
use vpe::platform::{dm3730, MpiModel, Soc};

/// Queued submits per wave (and the batched config's width cap).
const WAVE: usize = 8;
/// Steady-state waves measured.
const WAVES: usize = 12;

/// Stream `WAVES` waves of `WAVE` queued calls through the dispatch
/// queue and return the steady-state throughput in calls/sim-second.
fn run_pipeline(max_batch_width: usize) -> vpe::Result<(f64, Vpe)> {
    let mut cfg = VpeConfig::sim_only();
    cfg.exec_noise_frac = 0.0; // deterministic clock for the printout
    cfg.max_queue_per_target = WAVE; // room for a full wave in flight
    cfg.max_batch_width = max_batch_width;
    // No periodic analysis bursts: both runs stream the same call mix,
    // and the comparison should isolate the transport amortization.
    cfg.sampler.analysis_period = u64::MAX;
    let mut vpe = Vpe::with_policy(cfg, Box::new(AlwaysOffloadPolicy))?;
    // A BAAR-like remote server behind a fast cluster link: the ~100 ms
    // setup + round trip dominates a medium call; wire/serde stay per
    // call either way.
    *vpe.soc_mut() = Soc::dm3730_message_passing(MpiModel::cluster_10gbe());

    let f = vpe.register_matmul(128)?;
    // Warm-up: the first call profiles on the host and commits the
    // offload; the measurement starts at steady state.
    vpe.call(f)?;
    assert_eq!(vpe.current_target(f)?, dm3730::DSP, "offload must commit in warm-up");

    let t0 = vpe.clock().now_ns();
    for _ in 0..WAVES {
        for _ in 0..WAVE {
            vpe.submit(f)?;
        }
        let recs = vpe.drain()?;
        assert_eq!(recs.len(), WAVE, "every wave retires exactly once");
    }
    let elapsed_ns = vpe.clock().now_ns() - t0;
    let calls = (WAVES * WAVE) as f64;
    Ok((calls / (elapsed_ns as f64 / 1e9), vpe))
}

fn main() -> vpe::Result<()> {
    println!("== batched remote dispatch: {WAVES} waves x {WAVE} queued 128x128 matmuls ==");
    println!("   (message-passing SoC, 10 GbE-class link, ~100 ms setup per transport)\n");

    let (unbatched, v1) = run_pipeline(1)?;
    let (batched, v8) = run_pipeline(WAVE)?;

    println!("unbatched queue (width 1): {unbatched:7.2} calls/s");
    println!("batched queue   (width {WAVE}): {batched:7.2} calls/s");
    let speedup = batched / unbatched;
    println!("steady-state throughput:   {speedup:.2}x\n");

    // The unbatched run must never coalesce; the batched run coalesces
    // every wave and the saved-setup arithmetic is exact.
    assert_eq!(v1.batches_formed(), 0, "width 1 must not batch");
    let setup = v8
        .soc()
        .target(dm3730::DSP)?
        .transport
        .batch_setup_ns();
    assert_eq!(v8.batches_formed(), WAVES as u64, "one batch per wave");
    assert_eq!(v8.coalesced_dispatches(), (WAVES * (WAVE - 1)) as u64);
    assert_eq!(
        v8.saved_setup_ns(),
        (WAVES * (WAVE - 1)) as u64 * setup,
        "every wave must save exactly (width-1) * setup"
    );
    println!(
        "setup paid once per wave: saved {:.0} ms of transport setup over {} calls",
        v8.saved_setup_ns() as f64 / 1e6,
        WAVES * WAVE
    );

    // Exactly-once retirement and clean teardown on both queues.
    for v in [&v1, &v8] {
        assert_eq!(v.in_flight(), 0);
        assert_eq!(v.dispatches_submitted(), v.dispatches_retired());
        assert_eq!(v.soc().shared.used_bytes(), 0);
    }

    // The headline: batching lifts steady-state throughput >= 3x.
    assert!(
        speedup >= 3.0,
        "batching must lift steady-state throughput >= 3x, got {speedup:.2}x"
    );

    println!("\n{}", v8.report());
    println!(
        "same stream, same platform: coalescing same-target queue traffic into one \
         transport setup turns {unbatched:.1} calls/s into {batched:.1} calls/s ({speedup:.2}x)."
    );
    Ok(())
}
