//! Trace v3 end to end: record a *batched + sharded* mixed-backend run
//! and prove replay is decision-faithful — the acceptance demo for
//! batch/shard-aware replay.
//!
//! The recorded run exercises everything the old (v2) replay got wrong:
//!
//! - the policy is [`FanOutPolicy`], which decides from *batch-amortized*
//!   candidate prices (v2 recorded lone prices only, so these decisions
//!   silently diverged under replay);
//! - the hot matmul **fans out** across three units — one of them a real
//!   multicore rayon-backed engine (v2 replay treated `FanOut` as a
//!   no-op);
//! - the convolution stream is driven through `submit`/`drain` waves
//!   whose same-target dispatches **coalesce into batches** (v2 replay
//!   had no batch model, so amortized execution times were
//!   irreproducible).
//!
//! The assertions:
//!
//! 1. the v3 trace round-trips through JSON losslessly;
//! 2. replaying the trace under the *same* policy that recorded it
//!    reproduces the recorded decision sequence (zero divergences) and
//!    the recorded total **exactly, to the nanosecond** — noise,
//!    batching and fan-out makespans included;
//! 3. the replay understood the run: it prices coalesced followers and
//!    fan-out decisions rather than no-op'ing them.
//!
//! A what-if table across every policy closes the loop: the ablation
//! the paper's methodology needs, from one recording.
//!
//! `cargo run --release --example replay_whatif`

use vpe::coordinator::policies_ext::{
    EpsilonGreedyPolicy, FanOutPolicy, HysteresisPolicy, PredictivePolicy,
};
use vpe::coordinator::policy::{
    AlwaysOffloadPolicy, BlindOffloadPolicy, NeverOffloadPolicy, OffloadPolicy,
};
use vpe::coordinator::trace::{replay, Trace};
use vpe::coordinator::{Vpe, VpeConfig, VpeEvent};
use vpe::platform::{dm3730, BackendKind, TargetSpec, TransferModel, Transport};
use vpe::workloads::WorkloadKind;

/// Queued conv2d submits per wave (they coalesce into one batch).
const WAVE: usize = 5;
/// Steady-state waves after warm-up.
const WAVES: usize = 6;

/// Build the mixed platform: the DM3730 pair plus a second simulated
/// DSP-class unit and a real multicore (rayon thread-pool) unit, both
/// rated for matmul only — so the matmul sees three comparable
/// candidates (fan-out) while conv2d sees exactly one (plain offload).
fn build() -> vpe::Result<Vpe> {
    let mut cfg = VpeConfig::sim_only();
    cfg.max_queue_per_target = 8; // room for a full wave, no bounces
    cfg.max_batch_width = 8;
    cfg.rayon_threads = 2;
    // The conv2d stream is a modest share of the total cycles next to
    // the matmul; a lower nomination threshold lets the detector reach
    // it.  The threshold is recorded in the trace header, so replay
    // nominates under the same rule (the thresholds satellite).
    cfg.detector.share_threshold = 0.02;
    let mut v = Vpe::with_policy(cfg, Box::<FanOutPolicy>::default())?;
    for (name, rate, backend) in [
        ("dsp-b", 6.0, BackendKind::Default),
        ("multicore", 5.0, BackendKind::Rayon),
    ] {
        let id = v.soc_mut().add_target(
            TargetSpec::new(name, 1_000_000_000)
                .with_backend(backend)
                .with_transport(Transport::SharedMemory(TransferModel {
                    dispatch_fixed_ns: 5_000_000,
                    per_param_byte_ns: 1.0,
                })),
        );
        v.soc_mut().cost.set_rate(WorkloadKind::Matmul, id, rate);
    }
    Ok(v)
}

/// Record the run: sync warm-up until both decisions land, then waves
/// of queued traffic — batched conv2d plus fanned-out matmuls.
fn record() -> vpe::Result<(Trace, usize, usize)> {
    let mut v = build()?;
    v.enable_tracing();
    let mm = v.register_matmul(500)?;
    let conv = v.register_workload(WorkloadKind::Conv2d)?;

    for _ in 0..8 {
        v.call(mm)?;
        v.call(conv)?;
    }
    assert!(
        v.fanout_width(mm).is_some(),
        "the matmul must fan out in warm-up:\n{}",
        v.events().to_text()
    );
    assert_eq!(
        v.current_target(conv)?,
        dm3730::DSP,
        "conv2d must commit to the DSP:\n{}",
        v.events().to_text()
    );

    for _ in 0..WAVES {
        for _ in 0..WAVE {
            v.submit(conv)?;
        }
        v.submit(mm)?; // one sharded call rides along
        v.drain()?;
    }
    assert!(v.batches_formed() >= WAVES as u64, "waves must coalesce");
    assert_eq!(v.scheduler().bounce_count(), 0, "the run must stay bounce-free");
    assert_eq!(v.in_flight(), 0);

    let fanouts = v
        .events()
        .iter()
        .filter(|(_, e)| matches!(e, VpeEvent::FanOutChosen { .. }))
        .count();
    let offloads = v.events().offloads().len();
    Ok((v.trace().expect("tracing enabled").clone(), fanouts, offloads))
}

fn main() -> vpe::Result<()> {
    println!("== trace v3: batch/shard-aware replay ==");
    println!("   (FanOutPolicy on a 4-unit mixed sim+rayon platform;");
    println!("    {WAVES} waves of {WAVE} batched conv2d + 1 fanned-out matmul)\n");

    let (trace, live_fanouts, live_offloads) = record()?;
    println!(
        "recorded: {} calls, {:.1} ms, {} fan-out / {} offload decisions",
        trace.entries.len(),
        trace.total_ms(),
        live_fanouts,
        live_offloads
    );

    // 1. v3 round-trips losslessly through JSON.
    let back = Trace::from_json(&trace.to_json())?;
    assert_eq!(trace, back, "v3 JSON round-trip must be lossless");
    assert!(!back.degraded(), "a fresh trace carries full fidelity");
    println!("v3 JSON round-trip: lossless ({} bytes)", trace.to_json().len());

    // 2. The headline: replaying the recording policy reproduces the
    //    recorded decision sequence and total ns exactly.
    let mut same = FanOutPolicy::default();
    let o = replay(&back, &mut same);
    print!("\n{}", o.divergence_report());
    assert_eq!(
        o.diverged(),
        0,
        "recording-policy replay must reproduce every placement:\n{}",
        o.divergence_report()
    );
    assert_eq!(
        o.total_ns,
        trace.total_ns(),
        "recording-policy replay must re-price the run exactly, to the ns"
    );
    assert_eq!(o.fanouts, live_fanouts, "fan-out decisions must replay");
    assert_eq!(o.offloads, live_offloads, "offload decisions must replay");

    // 3. The replay actually modeled the phenomena (no no-ops).
    assert!(o.fanouts > 0, "the run must exercise fan-out");
    assert!(o.batched_calls > 0, "the run must exercise batch coalescing");
    assert!(!o.degraded_fidelity);

    // 4. What-if: re-price the same recording under every policy.
    let mut policies: Vec<Box<dyn OffloadPolicy>> = vec![
        Box::new(NeverOffloadPolicy),
        Box::new(AlwaysOffloadPolicy),
        Box::<BlindOffloadPolicy>::default(),
        Box::<HysteresisPolicy>::default(),
        Box::<PredictivePolicy>::default(),
        Box::<FanOutPolicy>::default(),
        Box::new(EpsilonGreedyPolicy::new(0.1, 0xE95)),
    ];
    println!(
        "\n{:<18} {:>10} {:>7} {:>7} {:>9} {:>8} {:>8} {:>9}",
        "policy", "total ms", "host", "remote", "offloads", "fanouts", "batched", "diverged"
    );
    for p in policies.iter_mut() {
        let o = replay(&trace, p.as_mut());
        println!(
            "{:<18} {:>10.1} {:>7} {:>7} {:>9} {:>8} {:>8} {:>9}",
            o.policy,
            o.total_ms,
            o.host_calls,
            o.remote_calls,
            o.offloads,
            o.fanouts,
            o.batched_calls,
            o.diverged()
        );
    }

    println!(
        "\nreplay is decision-faithful: the recording policy reproduces its own run \
         ns-exact,\nand counterfactual policies re-price batches and fan-outs for real."
    );
    Ok(())
}
