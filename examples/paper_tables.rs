//! Regenerate every table and figure of the paper's evaluation (§5) in
//! one run, and write them to `results/` as markdown + CSV.
//!
//! `cargo run --release --example paper_tables [-- --samples N --walls]`

use vpe::bench_harness::{fig2, fig3, table1};
use vpe::util::cli::Args;

fn main() -> vpe::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let samples: usize = args.opt("samples", 20)?;
    let walls = args.flag("walls");
    args.finish()?;

    std::fs::create_dir_all("results")?;
    let mut all = String::new();

    // -- Table 1 ----------------------------------------------------------
    let rows = table1::table1(samples, walls)?;
    let t = table1::render(&rows);
    println!("{}", t.to_markdown());
    std::fs::write("results/table1.csv", t.to_csv())?;
    all.push_str(&t.to_markdown());
    if walls {
        all.push_str("\nReal PJRT wall times (artifact shapes):\n");
        for r in &rows {
            if let (Some(nv), Some(dv)) = (r.wall_naive_ms, r.wall_dsp_ms) {
                all.push_str(&format!(
                    "- {}: naive {nv:.3} ms, pallas {dv:.3} ms\n",
                    r.kind.name()
                ));
            }
        }
    }

    // -- Fig 2a -----------------------------------------------------------
    let t = fig2::fig2a(samples)?;
    println!("{}", t.to_markdown());
    std::fs::write("results/fig2a.csv", t.to_csv())?;
    all.push_str(&t.to_markdown());

    // -- Fig 2b -----------------------------------------------------------
    let (points, tree) = fig2::fig2b(&fig2::default_sizes(), 5, 0xF162B);
    let t = fig2::render_fig2b(&points, &tree);
    println!("{}", t.to_markdown());
    std::fs::write("results/fig2b.csv", t.to_csv())?;
    all.push_str(&t.to_markdown());
    let cross = fig2::analytic_crossover();
    let learned = tree.root_threshold().unwrap_or(f64::NAN);
    let note = format!(
        "analytic crossover N = {cross:.0}; decision-tree learned N = {learned:.0} (paper: ~75)\n\n"
    );
    print!("{note}");
    all.push_str(&note);

    // -- Fig 3 ------------------------------------------------------------
    let s = fig3::fig3(300, 60, false)?;
    let t = fig3::render(&s);
    println!("{}", t.to_markdown());
    std::fs::write("results/fig3.csv", t.to_csv())?;
    all.push_str(&t.to_markdown());
    // Per-frame series for plotting.
    let mut series = String::from("frame,frame_ms,fps,cpu_load,target\n");
    for f in &s.frames {
        series.push_str(&format!(
            "{},{:.2},{:.3},{:.3},{}\n",
            f.frame,
            f.frame_ms,
            f.fps,
            f.cpu_load,
            if f.conv_target.is_host() { "arm" } else { "dsp" }
        ));
    }
    std::fs::write("results/fig3_series.csv", series)?;

    std::fs::write("results/all.md", &all)?;
    println!("written: results/table1.csv fig2a.csv fig2b.csv fig3.csv fig3_series.csv all.md");
    Ok(())
}
