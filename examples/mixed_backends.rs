//! Mixed execution backends — the per-target backend selection
//! acceptance demo.
//!
//! PR 1 made the *unit set* data; this example shows the *engine set*
//! is data too.  It builds a platform where two simulated DSP-class
//! units (`BackendKind::Sim`: calibrated timing, no numerics) sit next
//! to two real multicore units (`BackendKind::Rayon`: genuine thread
//! pools computing the reference numerics, wall-clocked), then:
//!
//! 1. lets the policy commit a hot matmul to the best-priced multicore
//!    unit and the cost-model learner replace its seeded rate with the
//!    *measured* wall-clock rate — asserting the learned row lands
//!    within 2x of the measured mean (the paper's warm-up-then-win
//!    loop, running on real hardware instead of calibrated constants);
//! 2. fans one large matmul out across *both* engine kinds at once and
//!    asserts the reassembled output is bit-exact against the
//!    reference — a batch never spans engines (batches are per-target),
//!    but a fan-out happily mixes them.
//!
//! `cargo run --release --example mixed_backends`

use std::collections::HashSet;

use vpe::coordinator::policy::AlwaysOffloadPolicy;
use vpe::coordinator::{Vpe, VpeConfig};
use vpe::platform::{BackendKind, TargetId, TargetSpec, TransferModel, Transport};
use vpe::workloads::{matmul_scale, WorkloadKind};

fn add_unit(vpe: &mut Vpe, name: &str, backend: BackendKind, seed_rate: f64) -> TargetId {
    let id = vpe.soc_mut().add_target(
        TargetSpec::new(name, 1_000_000_000)
            .with_backend(backend)
            .with_transport(Transport::SharedMemory(TransferModel {
                dispatch_fixed_ns: 1_000_000, // on-die-class link: 1 ms setup
                per_param_byte_ns: 1.0,
            })),
    );
    vpe.soc_mut().cost.set_rate(WorkloadKind::Matmul, id, seed_rate);
    id
}

fn main() -> vpe::Result<()> {
    let mut cfg = VpeConfig::default(); // reference numerics for default units
    cfg.exec_noise_frac = 0.0;
    cfg.learn_rates = true; // measured wall feeds the cost model
    cfg.rate_learn_alpha = 0.5;
    cfg.rayon_threads = 2;
    let mut vpe = Vpe::with_policy(cfg, Box::new(AlwaysOffloadPolicy))?;

    // -- the engine set is data ----------------------------------------------
    // Two simulated DSP-class units: calibrated physics, no numerics.
    let sim0 = add_unit(&mut vpe, "sim-dsp-0", BackendKind::Sim, 3.0);
    let sim1 = add_unit(&mut vpe, "sim-dsp-1", BackendKind::Sim, 3.6);
    // Two real multicore units: their seeded rates are deliberately
    // rough guesses — the learner will replace them with measurements.
    let mc0 = add_unit(&mut vpe, "multicore-0", BackendKind::Rayon, 0.6);
    let mc1 = add_unit(&mut vpe, "multicore-1", BackendKind::Rayon, 0.8);
    println!("platform: {} units", vpe.soc().registry.len());
    for (id, spec) in vpe.soc().targets() {
        println!("  [{id}] {:<24} engine {}", spec.name, vpe.backend_name_on(id));
    }

    // Register everything up front (the module finalizes at the first
    // call): the phase-1 stream at 128x128 and the phase-2 fan-out at
    // 512x512.
    let f = vpe.register_workload(WorkloadKind::Matmul)?; // 128x128
    let big = vpe.register_matmul(512)?;

    // -- phase 1: warm-up, then honest measured prices ------------------------
    let recs = vpe.run(f, 18)?;
    let committed = vpe.current_target(f)?;
    println!(
        "\nphase 1 — matmul committed to [{committed}] {} ({})",
        vpe.target_name(committed),
        vpe.backend_name_on(committed),
    );
    assert_eq!(committed, mc0, "the best-priced multicore unit must win");

    let items = matmul_scale(128).items;
    let measured: Vec<f64> = recs
        .iter()
        .filter(|r| r.target == mc0)
        .filter_map(|r| r.wall)
        .map(|w| w.as_nanos() as f64 / items)
        .collect();
    assert!(measured.len() >= 10, "multicore-0 must have served the stream");
    let mean = measured.iter().sum::<f64>() / measured.len() as f64;
    let learned = vpe.soc().cost.rate_ns(WorkloadKind::Matmul, mc0).expect("row");
    println!(
        "  measured {:>7.3} ns/item over {} calls | learned row {:>7.3} ns/item (seed 0.600)",
        mean,
        measured.len(),
        learned
    );
    assert!(
        learned / mean < 2.0 && mean / learned < 2.0,
        "learned rate {learned} must converge within 2x of measured {mean}"
    );
    // Every real execution verified against the reference oracle.
    assert!(recs
        .iter()
        .filter(|r| r.target == mc0)
        .all(|r| r.output_ok == Some(true)));
    // The ranking now prices the real engine from measurements.
    println!("  candidate ranking (honest prices after warm-up):");
    for c in vpe.candidates(f)? {
        println!(
            "    [{}] {:<24} predicted {:>9.3} ms",
            c.target,
            vpe.target_name(c.target),
            c.predicted_ns as f64 / 1e6
        );
    }

    // -- phase 2: one call fanned out across BOTH engine kinds ----------------
    let rec = vpe.call_sharded(big)?;
    let on: HashSet<TargetId> = vpe.events().shard_windows().iter().map(|w| w.0).collect();
    println!(
        "\nphase 2 — 512x512 matmul fanned out across {} shards on {:?} (makespan {:.3} ms)",
        rec.shards,
        {
            let mut names: Vec<String> = on.iter().map(|t| vpe.target_name(*t)).collect();
            names.sort();
            names
        },
        rec.exec_ns as f64 / 1e6
    );
    assert!(rec.shards >= 2, "must actually fan out: {rec:?}");
    assert_eq!(
        rec.output_ok,
        Some(true),
        "reassembly across sim + rayon engines must be bit-exact"
    );
    assert!(
        on.contains(&sim0) || on.contains(&sim1),
        "a simulated unit must take a shard: {on:?}"
    );
    assert!(
        on.contains(&mc0) || on.contains(&mc1),
        "a real multicore unit must take a shard: {on:?}"
    );
    assert_eq!(vpe.in_flight(), 0);
    assert_eq!(vpe.soc().shared.used_bytes(), 0);

    println!("\n{}", vpe.report());
    println!(
        "two engines behind one dispatch interface: simulated physics and a real \
         thread pool ranked, learned, and fanned out together."
    );
    Ok(())
}
