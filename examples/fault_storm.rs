//! Serving through a fault storm — the failure-recovery acceptance
//! proof.
//!
//! Four tenants hammer a [`SchedulerCore`] whose platform has three added
//! units (`serve-a` fastest — every dispatch slot pins to it) plus the
//! calibrated DSP, while a scripted, seeded [`FaultInjector`] runs a
//! storm in virtual time:
//!
//! - **kill** `serve-a` mid-burst (staged batches and in-flight work
//!   salvaged onto survivors), heal it later;
//! - **flap** `serve-b` — two fail/heal cycles;
//! - **degrade** `serve-c` 2.5x (thermal throttle), heal it later;
//! - a **flaky** 1% per-dispatch transient failure rate throughout,
//!   which also exercises the circuit breaker (threshold 1, 10 ms
//!   probes) — quarantine, half-open probe, close on success.
//!
//! Asserts, per the PR's acceptance criteria:
//!
//! - **exactly-once**: every admitted call resolves exactly once —
//!   zero stranded [`Completion`] handles, `submitted == retired`;
//! - **availability >= 99%**: calls that resolve with a typed error
//!   (retries exhausted) stay under 1%;
//! - **energy conservation through the storm**: on every unit, charged
//!   joules equal busy time x watts to the nanojoule — partial runs
//!   charged, un-run tails refunded;
//! - **no fidelity regression**: a fault-free run with the injector
//!   installed (empty script, zero flaky probability) records a v4
//!   trace that replays to exact ns and nJ.
//!
//! Emits `BENCH_recovery.json` through the shared
//! [`vpe::bench_harness::report`] writer (CI uploads it per run).
//!
//! `cargo run --release --example fault_storm [-- --smoke]`

use vpe::bench_harness::{BenchReport, BenchRow, Metric};
use vpe::coordinator::policy::AlwaysOffloadPolicy;
use vpe::coordinator::serving::{AdmitOutcome, Completion, SchedulerCore, TenantId};
use vpe::coordinator::trace::replay;
use vpe::coordinator::{CallOutcome, Vpe, VpeConfig};
use vpe::jit::module::FunctionId;
use vpe::platform::{TargetId, TargetSpec, TransferModel, Transport};
use vpe::sim::FaultInjector;
use vpe::workloads::{PaperScale, WorkloadKind};

/// Tenants sharing the serving core.
const TENANTS: usize = 4;
/// Retirements pumped per driver iteration.
const PUMP_BATCH: usize = 32;
/// Per-tenant mix weights over `[tiny, med, big]`.
const MIXES: [[u32; 3]; TENANTS] = [[6, 3, 1], [3, 5, 2], [2, 3, 5], [4, 4, 2]];

/// Deterministic arrival randomness (no wall clock anywhere).
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0 >> 33
    }

    fn pick(&mut self, weights: &[u32; 3], pool: &[FunctionId; 3]) -> FunctionId {
        let total: u32 = weights.iter().sum();
        let mut r = (self.next() % total as u64) as u32;
        for (w, f) in weights.iter().zip(pool) {
            if r < *w {
                return *f;
            }
            r -= w;
        }
        pool[2]
    }
}

/// The serving platform: three added units, `serve-a` strictly fastest
/// so every warm dispatch slot pins to it — the storm then kills
/// exactly the unit all traffic depends on.
fn build_platform() -> vpe::Result<(Vpe, [FunctionId; 3], [TargetId; 3])> {
    let mut cfg = VpeConfig::sim_only();
    cfg.tenant_quota = 16;
    cfg.max_inflight_total = 48;
    cfg.quarantine_threshold = 1; // one flake quarantines: breaker visible
    cfg.probe_interval_ns = 10_000_000; // 10 ms half-open probes
    let mut vpe = Vpe::with_policy(cfg, Box::new(AlwaysOffloadPolicy))?;

    let rates: [(&str, [f64; 3]); 3] = [
        ("serve-a", [1.0, 2.2, 1.5]),
        ("serve-b", [1.6, 3.0, 2.2]),
        ("serve-c", [2.0, 3.6, 2.6]),
    ];
    let kinds = [WorkloadKind::Dotprod, WorkloadKind::Conv2d, WorkloadKind::Matmul];
    let mut units = Vec::new();
    for (name, per_kind) in rates {
        let id = vpe.soc_mut().add_target(TargetSpec::new(name, 1_200_000_000).with_transport(
            Transport::SharedMemory(TransferModel {
                dispatch_fixed_ns: 1_500_000,
                per_param_byte_ns: 1.0,
            }),
        ));
        for (kind, rate) in kinds.iter().zip(per_kind) {
            vpe.soc_mut().cost.set_rate(*kind, id, rate);
        }
        units.push(id);
    }

    let tiny = vpe.register_workload(WorkloadKind::Dotprod)?;
    vpe.set_scale(tiny, PaperScale { items: 1e5, param_bytes: 48, payload_bytes: 4096 })?;
    let med = vpe.register_workload(WorkloadKind::Conv2d)?;
    vpe.set_scale(med, PaperScale { items: 1e6, param_bytes: 48, payload_bytes: 4096 })?;
    let big = vpe.register_matmul(128)?;

    let pool = [tiny, med, big];
    for f in pool {
        vpe.call(f)?; // host warm-up; the policy commits the offload
    }
    for f in pool {
        assert_eq!(vpe.current_target(f)?, units[0], "warm-up must pin every slot to serve-a");
    }
    Ok((vpe, pool, [units[0], units[1], units[2]]))
}

/// Fault-free fidelity leg: the recovery machinery installed but
/// dormant must not move a single nanosecond or nanojoule — the v4
/// trace of a run with an idle injector still replays exactly.
fn assert_replay_exact() -> vpe::Result<()> {
    let (mut vpe, pool, _) = build_platform()?;
    vpe.enable_tracing();
    vpe.set_fault_injector(FaultInjector::new(0xFA)); // empty script, 0.0 flaky
    for round in 0..40 {
        for f in pool {
            vpe.submit(f)?;
        }
        if round % 4 == 3 {
            vpe.drain()?;
        }
    }
    vpe.drain()?;
    let trace = vpe.trace().expect("tracing enabled").clone();
    let mut same = AlwaysOffloadPolicy;
    let o = replay(&trace, &mut same);
    assert_eq!(o.diverged(), 0, "idle-injector run must replay placement-exact");
    assert_eq!(o.total_ns, trace.total_ns(), "replay must re-price to the exact ns");
    assert_eq!(
        o.total_energy_nj,
        trace.total_energy_nj(),
        "replay must re-price to the exact nJ"
    );
    println!(
        "fidelity: idle-injector trace ({} entries) replays exactly — {} ns, {} nJ",
        trace.entries.len(),
        o.total_ns,
        o.total_energy_nj
    );
    Ok(())
}

fn main() -> vpe::Result<()> {
    let args = vpe::util::cli::Args::parse(std::env::args().skip(1))?;
    let smoke = args.flag("smoke");
    let total: usize = args.opt("calls", if smoke { 2_000 } else { 20_000 })?;
    args.finish()?;
    let per_tenant = total / TENANTS;
    let total = per_tenant * TENANTS;

    println!("== fault storm: {total} serving calls, {TENANTS} tenants, scripted kill/flap/degrade + 1% flaky ==\n");

    let (mut vpe, pool, [a, b, c]) = build_platform()?;
    let t0 = vpe.clock().now_ns();
    let ms = |x: u64| t0 + x * 1_000_000;
    // The storm, in virtual time relative to the end of warm-up: the
    // fastest unit dies mid-burst, a second flaps twice, a third
    // throttles — all while admitted traffic is in flight.
    vpe.set_fault_injector(
        FaultInjector::new(0x57)
            .fail_at(ms(8), a)
            .heal_at(ms(60), a)
            .fail_at(ms(15), b)
            .heal_at(ms(25), b)
            .fail_at(ms(35), b)
            .heal_at(ms(45), b)
            .degrade_at(ms(20), c, 2.5)
            .heal_at(ms(70), c)
            .with_flaky(0.01),
    );
    let quota = vpe.config().tenant_quota;
    // No event cap: the storm assertions read the full log (a capped
    // log drops the oldest entries — exactly the storm window).
    let mut server = SchedulerCore::new(vpe);

    let mut rng = Lcg(0xF0_57);
    let mut remaining = [per_tenant; TENANTS];
    let mut admitted = [0usize; TENANTS];
    let mut resolved = [0usize; TENANTS];
    let mut ok_calls = 0usize;
    let mut failed_calls = 0usize;
    let mut handles: Vec<Completion> = Vec::with_capacity(total);
    let mut violations = 0usize;
    let mut guard = 0usize;

    loop {
        guard += 1;
        assert!(guard < total * 60 + 10_000, "driver loop failed to make progress");

        let now = server.vpe().clock().now_ns();
        let mut backed_off: Option<u64> = None;
        for t in 0..TENANTS {
            if remaining[t] == 0 {
                continue;
            }
            let pending = admitted[t] - resolved[t];
            if pending >= quota / 2 {
                continue;
            }
            let mut burst = (quota - pending).min(remaining[t]);
            while burst > 0 {
                let f = rng.pick(&MIXES[t], &pool);
                match server.try_submit(TenantId(t as u32), f)? {
                    AdmitOutcome::Admitted(done) => {
                        handles.push(done);
                        admitted[t] += 1;
                        remaining[t] -= 1;
                        burst -= 1;
                    }
                    AdmitOutcome::Rejected { retry_after_ns, .. } => {
                        let at = now.saturating_add(retry_after_ns);
                        backed_off = Some(backed_off.map_or(at, |x: u64| x.min(at)));
                        break;
                    }
                }
            }
        }

        let mut progressed = false;
        for _ in 0..PUMP_BATCH {
            match server.pump()? {
                Some(rec) => {
                    progressed = true;
                    if let Some(TenantId(t)) = rec.tenant {
                        resolved[t as usize] += 1;
                        if rec.outcome == CallOutcome::Ok {
                            ok_calls += 1;
                        } else {
                            failed_calls += 1;
                        }
                    }
                }
                None => break,
            }
        }

        // Invariant sweep, every iteration: the accepted population is
        // bounded, and the queue books balance even while salvage is
        // re-packing dispatches mid-storm.  (Core invariants only —
        // salvage may legitimately overfill a survivor's queue, the
        // same carve-out the gauntlet's fault cells make.)
        violations += server.core_invariant_violations();

        if remaining.iter().all(|&r| r == 0) && server.is_idle() {
            break;
        }
        if !progressed {
            if let Some(at) = backed_off {
                server.idle_until(at);
            }
        }
    }

    let elapsed_ns = server.vpe().clock().now_ns() - t0;
    let elapsed_s = elapsed_ns as f64 / 1e9;
    let availability = ok_calls as f64 / (ok_calls + failed_calls) as f64;
    let (retries, rerouted, replanned, _) = server.vpe().recovery_counters();
    let ev = server.vpe().events();
    let target_failures = ev.target_failures().len();
    let recoveries = ev.target_recoveries().len();
    let quarantines = ev.quarantines().len();
    let stranded = handles.iter().filter(|h| !h.is_done()).count();

    println!(
        "storm: {target_failures} target failures, {recoveries} recoveries, {quarantines} quarantines"
    );
    println!(
        "recovery: {retries} retries, {rerouted} rerouted, {replanned} shards re-planned, {failed_calls} typed failures"
    );
    println!(
        "served {total} calls in {elapsed_s:.2} sim-s ({:.0} calls/s), availability {:.4}%",
        total as f64 / elapsed_s,
        availability * 100.0
    );

    // -- acceptance ---------------------------------------------------------
    assert_eq!(stranded, 0, "zero stranded Completion handles");
    let resolved_total: usize = resolved.iter().sum();
    assert_eq!(resolved_total, total, "every admitted call resolves exactly once");
    for (t, r) in resolved.iter().enumerate() {
        assert_eq!(*r, per_tenant, "tenant {t} resolved its full budget");
    }
    assert_eq!(violations, 0, "queue invariants held through the storm");
    assert!(availability >= 0.99, "availability floor: {:.4} < 0.99", availability);
    assert!(target_failures >= 3, "the scripted storm must have fired ({target_failures})");
    assert!(recoveries >= 3, "heals and probes must recover units ({recoveries})");
    assert!(quarantines >= 1, "the 1% flake must trip the breaker ({quarantines})");
    assert!(retries + rerouted >= 1, "salvage must actually engage");
    {
        let v = server.vpe();
        assert_eq!(v.in_flight(), 0);
        assert_eq!(v.dispatches_submitted(), v.dispatches_retired());
        assert_eq!(v.soc().shared.used_bytes(), 0, "no staging leaks");
        // Energy conservation through kill/flap/degrade: at the 1 W sim
        // default, charged joules equal busy nanoseconds on every unit
        // — partial runs charged, un-run tails refunded.
        for (id, _) in v.soc().targets() {
            assert_eq!(
                v.charged_energy_nj(id),
                v.scheduler().occupied_ns(id),
                "energy books must balance on {id} after the storm"
            );
        }
    }

    // -- fidelity: dormant machinery is a no-op -----------------------------
    assert_replay_exact()?;

    let (p50_ns, p99_ns) =
        server.vpe().serving_latency_percentiles().expect("completions recorded");
    let mut report = BenchReport::new("fault_storm", if smoke { "smoke" } else { "full" });
    report.push(
        BenchRow::new("storm")
            .metric("calls", Metric::Int(total as u64))
            .metric("throughput_calls_per_s", Metric::Fixed(total as f64 / elapsed_s, 1))
            .metric("p50_ms", Metric::Fixed(p50_ns as f64 / 1e6, 3))
            .metric("p99_ms", Metric::Fixed(p99_ns as f64 / 1e6, 3))
            .metric("saved_setup_ns", Metric::Int(server.vpe().saved_setup_ns()))
            .metric("energy_nj", Metric::Int(server.vpe().total_energy_nj()))
            .metric("availability", Metric::Fixed(availability, 6))
            .metric("tenants", Metric::Int(TENANTS as u64))
            .metric("sim_seconds", Metric::Fixed(elapsed_s, 3))
            .metric("typed_failures", Metric::Int(failed_calls as u64))
            .metric("retries", Metric::Int(retries))
            .metric("rerouted", Metric::Int(rerouted))
            .metric("shards_replanned", Metric::Int(replanned))
            .metric("target_failures", Metric::Int(target_failures as u64))
            .metric("recoveries", Metric::Int(recoveries as u64))
            .metric("quarantines", Metric::Int(quarantines as u64))
            .metric("stranded_handles", Metric::Int(stranded as u64))
            .metric("violations", Metric::Int(violations as u64))
            .metric("replay_exact", Metric::Bool(true)),
    );
    report.write(std::path::Path::new("BENCH_recovery.json"))?;
    println!("\nwrote BENCH_recovery.json");
    println!(
        "\n{total} calls through a kill/flap/degrade storm with 1% flaky dispatches: \
         {:.2}% availability, zero stranded handles, zero invariant violations, \
         energy books exact, and the dormant machinery replays bit-exact.",
        availability * 100.0
    );
    Ok(())
}
