"""AOT path tests: lowering produces loadable HLO text + consistent manifest."""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


class TestLowering:
    def test_hlo_text_is_parseable_hlo(self):
        text = aot.lower_one(
            model.naive_matmul,
            [jax.ShapeDtypeStruct((16, 16), jnp.int32)] * 2,
        )
        assert text.startswith("HloModule")
        assert "ROOT" in text
        # The interchange contract: entry returns a tuple (return_tuple=True).
        assert "->(s32[16,16]" in text.splitlines()[0]

    def test_pallas_lowering_has_no_custom_call(self):
        """interpret=True must lower to plain HLO the CPU client can run."""
        text = aot.lower_one(
            model.dsp_matmul,
            [jax.ShapeDtypeStruct((16, 16), jnp.int32)] * 2,
        )
        assert "custom-call" not in text.lower()

    def test_all_registered_artifacts_lower(self):
        # eval_shape is cheap; full lowering of every artifact is exercised
        # by `make artifacts`, here we sanity-check the registry itself.
        names = [a[0] for a in aot.ARTIFACTS]
        assert len(names) == len(set(names)), "duplicate artifact names"
        workloads = {a[1] for a in aot.ARTIFACTS}
        assert workloads == {
            "complement", "conv2d", "dotprod", "matmul", "pattern", "fft",
        }
        for _, _, _, fn, args in aot.ARTIFACTS:
            jax.eval_shape(fn, *args)  # must not raise


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestManifest:
    @property
    def root(self):
        return os.path.join(os.path.dirname(__file__), "../../artifacts")

    def test_manifest_covers_all_artifacts(self):
        with open(os.path.join(self.root, "manifest.json")) as f:
            manifest = json.load(f)
        assert manifest["format"] == "hlo-text"
        names = {e["name"] for e in manifest["artifacts"]}
        assert names == {a[0] for a in aot.ARTIFACTS}

    def test_manifest_files_exist_and_match_shapes(self):
        with open(os.path.join(self.root, "manifest.json")) as f:
            manifest = json.load(f)
        by_name = {a[0]: a for a in aot.ARTIFACTS}
        for e in manifest["artifacts"]:
            path = os.path.join(self.root, e["file"])
            assert os.path.exists(path), f"missing {path}"
            _, _, _, fn, args = by_name[e["name"]]
            assert [list(a.shape) for a in args] == [i["shape"] for i in e["inputs"]]
            out = jax.eval_shape(fn, *args)
            assert [list(o.shape) for o in out] == [o2["shape"] for o2 in e["outputs"]]
            assert [np.dtype(o.dtype).name for o in out] == [
                o2["dtype"] for o2 in e["outputs"]
            ]
