"""Kernel-vs-oracle correctness: the CORE numeric signal of the repo.

Every L1 Pallas kernel ("DSP build") and every naive jnp variant ("ARM
build") must agree with the independent pure-jnp oracle in
``compile.kernels.ref``.  Hypothesis sweeps sizes (within each kernel's
divisibility constraints) and input values.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref
from compile.kernels.complement import CHUNK as COMP_CHUNK
from compile.kernels.dotprod import CHUNK as DOT_CHUNK
from compile.kernels.pattern import CHUNK as PAT_CHUNK

SETTINGS = settings(max_examples=20, deadline=None)


def _ints(rng, lo, hi, shape):
    return jnp.asarray(rng.integers(lo, hi, shape), dtype=jnp.int32)


# --------------------------------------------------------------------------
# complement
# --------------------------------------------------------------------------

class TestComplement:
    @SETTINGS
    @given(seed=st.integers(0, 2**32 - 1), chunks=st.integers(1, 4))
    def test_dsp_matches_ref(self, seed, chunks):
        rng = np.random.default_rng(seed)
        seq = _ints(rng, 0, 4, COMP_CHUNK * chunks)
        got = model.dsp_complement(seq)[0]
        assert bool(jnp.all(got == ref.complement_ref(seq)))

    def test_naive_matches_ref(self):
        rng = np.random.default_rng(7)
        seq = _ints(rng, 0, 4, COMP_CHUNK)
        assert bool(
            jnp.all(model.naive_complement(seq)[0] == ref.complement_ref(seq))
        )

    def test_involution(self):
        """complement(complement(x)) == x — a paper-level invariant."""
        rng = np.random.default_rng(3)
        seq = _ints(rng, 0, 4, COMP_CHUNK)
        twice = model.dsp_complement(model.dsp_complement(seq)[0])[0]
        assert bool(jnp.all(twice == seq))

    def test_rejects_unaligned(self):
        with pytest.raises(AssertionError):
            model.dsp_complement(jnp.zeros(COMP_CHUNK + 1, dtype=jnp.int32))


# --------------------------------------------------------------------------
# conv2d
# --------------------------------------------------------------------------

class TestConv2d:
    @SETTINGS
    @given(
        seed=st.integers(0, 2**32 - 1),
        h=st.sampled_from([16, 32, 48, 128]),
        w=st.sampled_from([16, 33, 64, 128]),
        kk=st.sampled_from([1, 3, 5]),
    )
    def test_dsp_matches_ref(self, seed, h, w, kk):
        rng = np.random.default_rng(seed)
        img = _ints(rng, -8, 8, (h, w))
        ker = _ints(rng, -4, 4, (kk, kk))
        got = model.dsp_conv2d(img, ker)[0]
        assert bool(jnp.all(got == ref.conv2d_ref(img, ker)))

    @SETTINGS
    @given(seed=st.integers(0, 2**32 - 1), kk=st.sampled_from([3, 5]))
    def test_naive_matches_ref(self, seed, kk):
        rng = np.random.default_rng(seed)
        img = _ints(rng, -8, 8, (32, 32))
        ker = _ints(rng, -4, 4, (kk, kk))
        got = model.naive_conv2d(img, ker)[0]
        assert bool(jnp.all(got == ref.conv2d_ref(img, ker)))

    def test_identity_kernel(self):
        rng = np.random.default_rng(1)
        img = _ints(rng, -8, 8, (32, 32))
        ker = jnp.zeros((3, 3), dtype=jnp.int32).at[1, 1].set(1)
        assert bool(jnp.all(model.dsp_conv2d(img, ker)[0] == img))

    def test_linearity(self):
        """conv(a*img, k) == a*conv(img, k)."""
        rng = np.random.default_rng(2)
        img = _ints(rng, -8, 8, (32, 32))
        ker = _ints(rng, -4, 4, (3, 3))
        assert bool(
            jnp.all(
                model.dsp_conv2d(3 * img, ker)[0]
                == 3 * model.dsp_conv2d(img, ker)[0]
            )
        )


# --------------------------------------------------------------------------
# dotprod
# --------------------------------------------------------------------------

class TestDotprod:
    @SETTINGS
    @given(seed=st.integers(0, 2**32 - 1), chunks=st.integers(1, 4))
    def test_dsp_matches_ref(self, seed, chunks):
        rng = np.random.default_rng(seed)
        x = _ints(rng, -8, 8, DOT_CHUNK * chunks)
        y = _ints(rng, -8, 8, DOT_CHUNK * chunks)
        assert int(model.dsp_dotprod(x, y)[0]) == int(ref.dotprod_ref(x, y))

    def test_naive_matches_ref(self):
        rng = np.random.default_rng(11)
        x = _ints(rng, -8, 8, DOT_CHUNK)
        y = _ints(rng, -8, 8, DOT_CHUNK)
        assert int(model.naive_dotprod(x, y)[0]) == int(ref.dotprod_ref(x, y))

    def test_orthogonal(self):
        x = jnp.zeros(DOT_CHUNK, dtype=jnp.int32).at[0].set(5)
        y = jnp.zeros(DOT_CHUNK, dtype=jnp.int32).at[1].set(7)
        assert int(model.dsp_dotprod(x, y)[0]) == 0


# --------------------------------------------------------------------------
# matmul
# --------------------------------------------------------------------------

class TestMatmul:
    @SETTINGS
    @given(seed=st.integers(0, 2**32 - 1), n=st.sampled_from([16, 32, 64, 128]))
    def test_dsp_matches_ref(self, seed, n):
        rng = np.random.default_rng(seed)
        a = _ints(rng, -8, 8, (n, n))
        b = _ints(rng, -8, 8, (n, n))
        got = model.dsp_matmul(a, b)[0]
        assert bool(jnp.all(got == ref.matmul_ref(a, b)))

    def test_rectangular(self):
        rng = np.random.default_rng(5)
        a = _ints(rng, -8, 8, (32, 64))
        b = _ints(rng, -8, 8, (64, 16))
        got = model.dsp_matmul(a, b)[0]
        assert bool(jnp.all(got == ref.matmul_ref(a, b)))

    def test_identity(self):
        rng = np.random.default_rng(6)
        a = _ints(rng, -8, 8, (32, 32))
        eye = jnp.eye(32, dtype=jnp.int32)
        assert bool(jnp.all(model.dsp_matmul(a, eye)[0] == a))

    def test_naive_matches_ref(self):
        rng = np.random.default_rng(12)
        a = _ints(rng, -8, 8, (64, 64))
        b = _ints(rng, -8, 8, (64, 64))
        assert bool(jnp.all(model.naive_matmul(a, b)[0] == ref.matmul_ref(a, b)))

    def test_ablation_blocks_match_ref(self):
        """The L1 tile-size ablation builds stay correct."""
        rng = np.random.default_rng(13)
        a = _ints(rng, -8, 8, (64, 64))
        b = _ints(rng, -8, 8, (64, 64))
        want = ref.matmul_ref(a, b)
        for fn in [model.dsp_matmul_b8, model.dsp_matmul_b32]:
            assert bool(jnp.all(fn(a, b)[0] == want)), fn.__name__

    def test_small_sizes_clamp_the_block(self):
        # Sizes below DEFAULT_BLOCK clamp the tile (17 -> 17x17 tiles).
        rng = np.random.default_rng(8)
        a = _ints(rng, -8, 8, (17, 17))
        b = _ints(rng, -8, 8, (17, 17))
        assert bool(jnp.all(model.dsp_matmul(a, b)[0] == ref.matmul_ref(a, b)))

    def test_rejects_unaligned(self):
        # 40 is not a multiple of the clamped 32-tile.
        with pytest.raises(AssertionError):
            model.dsp_matmul(
                jnp.zeros((40, 40), dtype=jnp.int32),
                jnp.zeros((40, 40), dtype=jnp.int32),
            )


# --------------------------------------------------------------------------
# pattern
# --------------------------------------------------------------------------

class TestPattern:
    @SETTINGS
    @given(
        seed=st.integers(0, 2**32 - 1),
        chunks=st.integers(1, 3),
        plen=st.sampled_from([2, 4, 8, 16]),
    )
    def test_dsp_matches_ref(self, seed, chunks, plen):
        rng = np.random.default_rng(seed)
        seq = _ints(rng, 0, 4, PAT_CHUNK * chunks)
        pat = _ints(rng, 0, 4, plen)
        assert int(model.dsp_pattern(seq, pat)[0]) == int(ref.pattern_ref(seq, pat))

    def test_known_count(self):
        # 'ACGT' repeated: pattern 'ACGT' occurs at every 4th position.
        n = PAT_CHUNK
        seq = jnp.tile(jnp.arange(4, dtype=jnp.int32), n // 4)
        pat = jnp.arange(4, dtype=jnp.int32)
        # Starts 0,4,...; last full window starts at n-4.
        assert int(model.dsp_pattern(seq, pat)[0]) == n // 4

    def test_no_match(self):
        seq = jnp.zeros(PAT_CHUNK, dtype=jnp.int32)
        pat = jnp.ones(8, dtype=jnp.int32)
        assert int(model.dsp_pattern(seq, pat)[0]) == 0

    def test_tail_window_not_counted(self):
        """A prefix of the pattern at the very end must not count."""
        seq = jnp.zeros(PAT_CHUNK, dtype=jnp.int32).at[-4:].set(1)
        pat = jnp.ones(8, dtype=jnp.int32)
        assert int(model.dsp_pattern(seq, pat)[0]) == int(ref.pattern_ref(seq, pat))


# --------------------------------------------------------------------------
# fft
# --------------------------------------------------------------------------

class TestFft:
    @SETTINGS
    @given(
        seed=st.integers(0, 2**32 - 1),
        n=st.sampled_from([2, 8, 64, 256, 1024]),
    )
    def test_dsp_matches_ref(self, seed, n):
        rng = np.random.default_rng(seed)
        re = jnp.asarray(rng.normal(size=n), dtype=jnp.float32)
        im = jnp.asarray(rng.normal(size=n), dtype=jnp.float32)
        got = model.dsp_fft(re, im)[0]
        want = ref.fft_ref(re, im)
        np.testing.assert_allclose(got, want, atol=1e-3 * np.sqrt(n))

    def test_impulse(self):
        """FFT of a unit impulse is all-ones."""
        n = 64
        re = jnp.zeros(n, dtype=jnp.float32).at[0].set(1.0)
        im = jnp.zeros(n, dtype=jnp.float32)
        got = model.dsp_fft(re, im)[0]
        np.testing.assert_allclose(got[0], np.ones(n), atol=1e-5)
        np.testing.assert_allclose(got[1], np.zeros(n), atol=1e-5)

    def test_parseval(self):
        """sum |x|^2 == sum |X|^2 / N."""
        rng = np.random.default_rng(9)
        n = 256
        re = jnp.asarray(rng.normal(size=n), dtype=jnp.float32)
        im = jnp.asarray(rng.normal(size=n), dtype=jnp.float32)
        got = model.dsp_fft(re, im)[0]
        t = float(jnp.sum(re**2 + im**2))
        f = float(jnp.sum(got[0] ** 2 + got[1] ** 2)) / n
        np.testing.assert_allclose(t, f, rtol=1e-4)

    def test_rejects_non_pow2(self):
        with pytest.raises(AssertionError):
            model.dsp_fft(
                jnp.zeros(100, dtype=jnp.float32),
                jnp.zeros(100, dtype=jnp.float32),
            )
