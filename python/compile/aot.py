"""AOT driver: lower every (workload, variant) to HLO text + a manifest.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
bundled XLA (xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids, so text round-trips cleanly.  See
/opt/xla-example/README.md.

Run as ``python -m compile.aot --out-dir ../artifacts`` (from python/),
which is what ``make artifacts`` does.  Python runs ONCE at build time;
the Rust coordinator only ever touches ``artifacts/``.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

# Artifact shapes: the concrete shapes the Rust runtime executes for real
# numerics.  Paper-*scale* parameters (64 Mi-char sequences etc.) live in
# the Rust cost model; AOT artifacts use sizes that compile and run in
# milliseconds on the CPU PJRT substrate.
I32 = jnp.int32
F32 = jnp.float32


def sd(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# Matmul sizes AOT'd for the Fig 2b sweep (simulated sweep covers 16..512;
# these are the sizes executed for real).
MATMUL_SIZES = [16, 32, 64, 128]

ARTIFACTS = []  # (name, workload, variant, fn, example_args, params)


def _register_all():
    ARTIFACTS.clear()
    specs = {
        "complement": [sd((65536,), I32)],
        "conv2d": [sd((128, 128), I32), sd((3, 3), I32)],
        "dotprod": [sd((262144,), I32), sd((262144,), I32)],
        "pattern": [sd((65536,), I32), sd((16,), I32)],
        "fft": [sd((1024,), F32), sd((1024,), F32)],
    }
    for workload, args in specs.items():
        for variant, fn in model.VARIANTS[workload].items():
            ARTIFACTS.append((f"{workload}__{variant}", workload, variant, fn, args))
    for n in MATMUL_SIZES:
        args = [sd((n, n), I32), sd((n, n), I32)]
        for variant, fn in model.VARIANTS["matmul"].items():
            ARTIFACTS.append((f"matmul{n}__{variant}", "matmul", variant, fn, args))
    # L1 tile-size ablation builds (EXPERIMENTS.md §Perf): same matmul,
    # different Pallas block shapes, measured against each other by
    # `cargo bench --bench kernel_blocks`.
    args128 = [sd((128, 128), I32), sd((128, 128), I32)]
    ARTIFACTS.append(("matmul128__dsp_b8", "matmul", "dsp_b8", model.dsp_matmul_b8, args128))
    ARTIFACTS.append(("matmul128__dsp_b32", "matmul", "dsp_b32", model.dsp_matmul_b32, args128))


_register_all()


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(fn, example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    # The HLO text printer elides constants wider than a few lanes as
    # ``constant({...})``; xla_extension 0.5.1's text parser reads those
    # back as garbage.  Refuse to emit such an artifact — restructure the
    # kernel to compute the values (iota/cos/...) instead of embedding
    # them (see kernels/fft.py for the pattern).
    if "{...}" in text:
        raise ValueError(
            f"{fn.__name__}: lowered HLO contains an elided constant "
            "('constant({...})'); the Rust runtime would mis-execute it"
        )
    return text


def _dtype_name(dt) -> str:
    return np.dtype(dt).name


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {"format": "hlo-text", "artifacts": []}
    for name, workload, variant, fn, example_args in ARTIFACTS:
        if only is not None and name not in only:
            continue
        text = lower_one(fn, example_args)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(fn, *example_args)
        entry = {
            "name": name,
            "workload": workload,
            "variant": variant,
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"shape": list(a.shape), "dtype": _dtype_name(a.dtype)}
                for a in example_args
            ],
            "outputs": [
                {"shape": list(o.shape), "dtype": _dtype_name(o.dtype)}
                for o in out_shapes
            ],
        }
        manifest["artifacts"].append(entry)
        print(f"  lowered {name:24s} -> {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
