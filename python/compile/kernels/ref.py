"""Pure-jnp correctness oracles for every workload.

These are the reference implementations the Pallas kernels (and the naive
jnp variants) are validated against in ``python/tests``.  They intentionally
use *different* jnp formulations than the kernels (e.g. ``lax.conv`` instead
of shift-and-add, ``jnp.fft`` instead of unrolled butterflies) so that a bug
in a kernel cannot be mirrored in its oracle.

All functions are shape-polymorphic pure functions of jnp arrays.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

# DNA alphabet encoding used across the repo: A=0, C=1, G=2, T=3.
# The complement swaps A<->T and C<->G, i.e. ``code -> 3 - code``.
DNA_ALPHABET = 4


def complement_ref(seq: jnp.ndarray) -> jnp.ndarray:
    """Complementary nucleotidic sequence: A<->T, C<->G (codes 0..3)."""
    # Table-lookup formulation (the paper's C code uses a lookup table).
    table = jnp.array([3, 2, 1, 0], dtype=seq.dtype)
    return jnp.take(table, seq)


def conv2d_ref(img: jnp.ndarray, kernel: jnp.ndarray) -> jnp.ndarray:
    """2-D cross-correlation, SAME padding, via lax.conv_general_dilated."""
    img_f = img.astype(jnp.float32)[None, None, :, :]
    ker_f = kernel.astype(jnp.float32)[None, None, :, :]
    out = lax.conv_general_dilated(
        img_f, ker_f, window_strides=(1, 1), padding="SAME"
    )
    return out[0, 0].astype(img.dtype)


def dotprod_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Dot product of two vectors (scalar output).

    Accumulates in the input dtype (int32 for the benchmark): generators
    keep values in [-8, 8) so the exact sum fits comfortably.
    """
    return jnp.dot(x, y)


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Square matrix multiplication."""
    return jnp.matmul(a, b)


def pattern_ref(seq: jnp.ndarray, pat: jnp.ndarray) -> jnp.ndarray:
    """Count occurrences of ``pat`` in ``seq`` (all start positions)."""
    n, p = seq.shape[0], pat.shape[0]
    nwin = n - p + 1
    acc = jnp.ones((nwin,), dtype=jnp.int32)
    for off in range(p):
        acc = acc * (seq[off : off + nwin] == pat[off]).astype(jnp.int32)
    return jnp.sum(acc).astype(jnp.int32)


def fft_ref(re: jnp.ndarray, im: jnp.ndarray) -> jnp.ndarray:
    """FFT oracle via jnp.fft; returns stacked (2, N) [real; imag]."""
    z = jnp.fft.fft(re.astype(jnp.complex64) + 1j * im.astype(jnp.complex64))
    return jnp.stack([jnp.real(z), jnp.imag(z)]).astype(jnp.float32)
