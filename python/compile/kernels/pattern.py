"""L1 Pallas kernel: DNA pattern search (the "DSP build").

Counts the occurrences of a length-P pattern in a code sequence.  The
C64x+ wins 22.7x on this workload by software-pipelining packed compares;
the Pallas analog blocks the *window start positions* across the grid and
turns the P inner compares into P full-width vector compare-and-multiply
steps over a VMEM-resident chunk (+ halo).

The caller pads the sequence with P-1 sentinel values (-1, outside the
DNA alphabet) so every program sees a full chunk of windows and no
boundary branches exist in the hot loop.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 4096  # window start positions per grid program


def _pattern_kernel(seq_ref, pat_ref, o_ref, *, plen: int, chunk: int):
    i = pl.program_id(0)
    base = i * chunk
    acc = jnp.ones((chunk,), dtype=jnp.int32)
    for off in range(plen):
        window = seq_ref[pl.dslice(base + off, chunk)]
        acc = acc * (window == pat_ref[off]).astype(jnp.int32)
    o_ref[0] = jnp.sum(acc)


def pattern_count(seq: jnp.ndarray, pat: jnp.ndarray) -> jnp.ndarray:
    """Count matches of ``pat`` at every start position of ``seq``.

    len(seq) % CHUNK == 0.  Start positions in the last P-1 places cannot
    match (sentinel padding) which agrees with the N-P+1 window semantics
    of the reference as long as the pattern contains no sentinel.
    """
    n = seq.shape[0]
    plen = pat.shape[0]
    assert n % CHUNK == 0, f"sequence length {n} must be a multiple of {CHUNK}"
    grid = n // CHUNK
    padded = jnp.concatenate(
        [seq, jnp.full((plen - 1,), -1, dtype=seq.dtype)]
    )
    kern = lambda s, p, o: _pattern_kernel(s, p, o, plen=plen, chunk=CHUNK)
    partials = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((grid,), jnp.int32),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(padded.shape, lambda i: (0,)),
            pl.BlockSpec(pat.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        interpret=True,
    )(padded, pat)
    return jnp.sum(partials).astype(jnp.int32)
