"""L1 Pallas kernel: integer dot product (the "DSP build" of the dot loop).

The C64x+ pipelines a multiply-accumulate loop over its dual multipliers;
the Pallas analog is a chunked grid where each program reduces one
VMEM-resident chunk to a partial sum, and the (tiny) partial vector is
reduced by the caller.  This keeps every load feeding a fused
multiply-accumulate — the same insight the TI compiler's software
pipeliner exploits.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Chunk size: raised 8192 -> 32768 in the §Perf pass (EXPERIMENTS.md):
# fewer grid steps cut the interpret-lowered while-loop overhead 4x on
# the PJRT CPU substrate while 128 KiB per buffer still fits an L2-ish
# working set.
CHUNK = 32768


def _dot_chunk_kernel(x_ref, y_ref, o_ref):
    o_ref[0] = jnp.sum(x_ref[...] * y_ref[...])


def dotprod(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Chunked dot product; len(x) % CHUNK == 0. Returns a scalar."""
    n = x.shape[0]
    assert n % CHUNK == 0, f"vector length {n} must be a multiple of {CHUNK}"
    grid = n // CHUNK
    partials = pl.pallas_call(
        _dot_chunk_kernel,
        out_shape=jax.ShapeDtypeStruct((grid,), x.dtype),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((CHUNK,), lambda i: (i,)),
            pl.BlockSpec((CHUNK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        interpret=True,
    )(x, y)
    return jnp.sum(partials)
