"""L1 Pallas kernel: blocked 2-D cross-correlation (the "DSP build").

The paper's image-processing prototype runs a contour-detection
convolution.  The DSP's advantage is a software-pipelined inner loop with
the kernel taps held in registers; the Pallas analog blocks the *output*
rows across the grid, keeps the (already padded) input rows for the block
plus halo in fast memory, and unrolls the k*k taps as shift-multiply-add
over full vector rows.

The caller pads the image (SAME padding) so the kernel only does regular
full-width arithmetic — no branches in the hot loop, exactly what a
pipelining compiler needs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 16


def _conv_kernel(img_ref, ker_ref, o_ref, *, kk: int, row_block: int, width: int):
    i = pl.program_id(0)
    # Rows for this output block plus the (kk-1)-row halo.
    rows = img_ref[pl.dslice(i * row_block, row_block + kk - 1), :]
    acc = jnp.zeros((row_block, width), dtype=o_ref.dtype)
    for dy in range(kk):
        for dx in range(kk):
            tap = ker_ref[dy, dx]
            acc = acc + tap * rows[dy : dy + row_block, dx : dx + width]
    o_ref[...] = acc


def conv2d(img: jnp.ndarray, kernel: jnp.ndarray, row_block: int = ROW_BLOCK) -> jnp.ndarray:
    """Blocked SAME cross-correlation. H % row_block == 0, odd square kernel."""
    h, w = img.shape
    kk = kernel.shape[0]
    assert kernel.shape == (kk, kk) and kk % 2 == 1, "kernel must be odd square"
    assert h % row_block == 0, f"height {h} must be a multiple of {row_block}"
    pad = kk // 2
    padded = jnp.pad(img, pad)  # (h + kk - 1, w + kk - 1)
    grid = (h // row_block,)
    kern = lambda a, b, o: _conv_kernel(
        a, b, o, kk=kk, row_block=row_block, width=w
    )
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((h, w), img.dtype),
        grid=grid,
        in_specs=[
            # Full padded image visible to every program (halo access).
            pl.BlockSpec(padded.shape, lambda i: (0, 0)),
            pl.BlockSpec(kernel.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_block, w), lambda i: (i, 0)),
        interpret=True,
    )(padded, kernel)
