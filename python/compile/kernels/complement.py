"""L1 Pallas kernel: DNA complement (the "DSP build" of the complement loop).

The paper's C64x+ win on this workload comes from software pipelining a
byte-lookup loop across 8 VLIW units.  The Pallas analog: block the sequence
into VMEM-sized chunks (grid dimension) and complement each chunk with a
single vectorized arithmetic op (``3 - x`` — the lookup table for the 2-bit
DNA code collapses to arithmetic, exactly what a pipelining compiler finds).

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO that the Rust runtime
executes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Chunk size: 8192 int32 lanes = 32 KiB per buffer, comfortably inside a
# C64x+-style scratchpad (and a TPU VMEM tile).
CHUNK = 8192


def _complement_kernel(x_ref, o_ref):
    # A<->T, C<->G over the 2-bit code: table [3,2,1,0] == 3 - x.
    o_ref[...] = 3 - x_ref[...]


def complement(seq: jnp.ndarray) -> jnp.ndarray:
    """Blocked complement of a code-0..3 sequence. len(seq) % CHUNK == 0."""
    n = seq.shape[0]
    assert n % CHUNK == 0, f"sequence length {n} must be a multiple of {CHUNK}"
    grid = n // CHUNK
    return pl.pallas_call(
        _complement_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), seq.dtype),
        grid=(grid,),
        in_specs=[pl.BlockSpec((CHUNK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((CHUNK,), lambda i: (i,)),
        interpret=True,
    )(seq)
