"""L1 Pallas kernel: tiled integer matrix multiply (the "DSP build").

The paper's biggest win (31.9x) comes from the TI compiler software-
pipelining the triple loop.  On the TPU-ish model the same insight is a
blocked schedule: tiles of A and B staged into VMEM (BlockSpec), a grid
over (M/bm, N/bn, K/bk), and an accumulation loop over the K grid
dimension feeding the matrix unit.  ``@pl.when`` zeroes the accumulator
tile on the first K step — the canonical Pallas matmul pattern.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile size.  Chosen by the EXPERIMENTS.md §Perf ablation
# (`cargo bench --bench kernel_blocks`): 32x32 tiles run the 128x128
# artifact 3.7x faster than 16x16 on the interpret-lowered CPU substrate
# (fewer grid steps = less while-loop overhead in the lowered HLO) while
# still fitting a C64x+-class scratchpad at int32 (3 * 32*32*4 B = 12 KiB)
# and mapping onto MXU sub-tiles.  Sizes smaller than the block clamp
# down automatically (matmul16 uses 16x16).
DEFAULT_BLOCK = 32


def _matmul_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


def matmul(a: jnp.ndarray, b: jnp.ndarray, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Tiled matmul; all dims must be multiples of ``block``."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm = bn = bk = min(block, m, n, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"dims ({m},{k},{n}) must be multiples of block {bm}"
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        interpret=True,
    )(a, b)
