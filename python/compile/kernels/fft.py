"""L1 Pallas kernel: iterative radix-2 FFT (the "DSP build").

This is the paper's *negative* case: the FFT is float-heavy and the C64x+
has no hardware floating point, so VPE's blind offload loses (0.7x) and
the policy must revert.  We still build the kernel for real — a fully
unrolled iterative Cooley-Tukey DIT over split real/imaginary planes —
because VPE executes it before discovering the regression.

Structure notes (all three shaped by xla_extension 0.5.1, the Rust
runtime's XLA, whose HLO *text* round-trip is the interchange format):

- the bit-reversal input permutation is done by the caller as a
  reshape-to-(2,)*log2(N) + axis-reversal transpose — gather-free (the
  0.5.1 text parser mis-executes constant-index gathers), and the moral
  equivalent of DSP bit-reversed addressing;
- twiddle factors are computed *inside* the kernel from `iota` + cos/sin
  rather than embedded as constant tables: the HLO text printer elides
  any constant wider than a few lanes as ``constant({...})``, which the
  text parser then reads back as garbage;
- each butterfly stage is expressed as full-width vector ops on a
  (N/2m, 2, m) view — top' = top + w*bot, bot' = top - w*bot — and the
  output is written with a single whole-buffer store.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _bit_reverse(x: jnp.ndarray) -> jnp.ndarray:
    """Bit-reversal permutation, gather-free (see module docs)."""
    n = x.shape[0]
    bits = n.bit_length() - 1
    cube = x.reshape((2,) * bits)
    return cube.transpose(tuple(reversed(range(bits)))).reshape(-1)


def _fft_kernel(re_ref, im_ref, o_ref, *, n: int):
    re = re_ref[...]
    im = im_ref[...]
    m = 1
    while m < n:
        # Twiddles for this stage: w_j = exp(-i pi j / m), j < m.
        # iota-derived (not a constant table) — see module docs.
        j = lax.broadcasted_iota(jnp.float32, (m,), 0)
        ang = -(np.pi / m) * j
        tw_re = jnp.cos(ang)
        tw_im = jnp.sin(ang)
        re3 = re.reshape(-1, 2, m)
        im3 = im.reshape(-1, 2, m)
        top_re, bot_re = re3[:, 0, :], re3[:, 1, :]
        top_im, bot_im = im3[:, 0, :], im3[:, 1, :]
        # w * bot
        wb_re = bot_re * tw_re - bot_im * tw_im
        wb_im = bot_re * tw_im + bot_im * tw_re
        re = jnp.stack([top_re + wb_re, top_re - wb_re], axis=1).reshape(-1)
        im = jnp.stack([top_im + wb_im, top_im - wb_im], axis=1).reshape(-1)
        m *= 2
    # Single whole-buffer store (row-indexed ref writes lower to a
    # scatter pattern 0.5.1 cannot run).
    o_ref[...] = jnp.stack([re, im])


def fft(re: jnp.ndarray, im: jnp.ndarray) -> jnp.ndarray:
    """Radix-2 DIT FFT; N must be a power of two. Returns (2, N) [re; im]."""
    n = re.shape[0]
    assert n & (n - 1) == 0 and n >= 2, f"N={n} must be a power of two"
    kern = lambda a, b, o: _fft_kernel(a, b, o, n=n)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((2, n), jnp.float32),
        interpret=True,
    )(_bit_reverse(re), _bit_reverse(im))
