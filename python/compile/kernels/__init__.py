"""L1 Pallas kernels — the "DSP builds" of the six VPE benchmark loops.

Each module exposes one entry point used by the L2 model:

- :func:`complement.complement` — blocked DNA complement
- :func:`conv2d.conv2d` — blocked SAME 2-D cross-correlation
- :func:`dotprod.dotprod` — chunked integer dot product
- :func:`matmul.matmul` — tiled integer matrix multiply
- :func:`pattern.pattern_count` — blocked pattern-occurrence count
- :func:`fft.fft` — unrolled iterative radix-2 FFT (the paper's 0.7x case)

All kernels run with ``interpret=True`` so they lower to plain HLO that the
Rust PJRT-CPU runtime can execute; correctness is asserted against the
pure-jnp oracles in :mod:`ref`.
"""

from . import complement, conv2d, dotprod, fft, matmul, pattern, ref  # noqa: F401
