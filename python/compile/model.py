"""L2: the six VPE benchmark computations as jitted JAX functions.

Two build variants exist for every workload, mirroring the paper's setup:

- ``naive_*``  — plain jnp, the "ARM -O3 build" the developer wrote;
- ``dsp_*``    — calls the L1 Pallas kernel, the "TI-compiler DSP build"
  produced by VPE's toolchain scripts (paper §4).

Every function returns a 1-tuple so the AOT path can lower with
``return_tuple=True`` and the Rust side can unwrap with ``to_tuple1()``
(see /opt/xla-example/README.md).

These functions are *build-time only*: ``aot.py`` lowers them to HLO text
once, and the Rust coordinator executes the artifacts through PJRT.  Python
never runs on the request path.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import complement as k_complement
from .kernels import conv2d as k_conv2d
from .kernels import dotprod as k_dotprod
from .kernels import fft as k_fft
from .kernels import matmul as k_matmul
from .kernels import pattern as k_pattern
from .kernels import ref


# --------------------------------------------------------------------------
# complement
# --------------------------------------------------------------------------

def naive_complement(seq):
    """Lookup-table complement, as the paper's C loop compiles on ARM."""
    table = jnp.array([3, 2, 1, 0], dtype=seq.dtype)
    return (jnp.take(table, seq),)


def dsp_complement(seq):
    return (k_complement.complement(seq),)


# --------------------------------------------------------------------------
# conv2d
# --------------------------------------------------------------------------

def naive_conv2d(img, kernel):
    """Shift-and-add SAME cross-correlation in plain jnp."""
    h, w = img.shape
    kk = kernel.shape[0]
    pad = kk // 2
    padded = jnp.pad(img, pad)
    acc = jnp.zeros((h, w), dtype=img.dtype)
    for dy in range(kk):
        for dx in range(kk):
            acc = acc + kernel[dy, dx] * padded[dy : dy + h, dx : dx + w]
    return (acc,)


def dsp_conv2d(img, kernel):
    return (k_conv2d.conv2d(img, kernel),)


# --------------------------------------------------------------------------
# dotprod
# --------------------------------------------------------------------------

def naive_dotprod(x, y):
    return (jnp.dot(x, y),)


def dsp_dotprod(x, y):
    return (k_dotprod.dotprod(x, y),)


# --------------------------------------------------------------------------
# matmul
# --------------------------------------------------------------------------

def naive_matmul(a, b):
    # einsum keeps the naive build on a (slightly) different lowering path
    # than the matmul_ref oracle.
    return (jnp.einsum("ik,kj->ij", a, b),)


def dsp_matmul(a, b):
    return (k_matmul.matmul(a, b),)


def dsp_matmul_b8(a, b):
    """L1 ablation build: 8x8 tiles (under-feeds the vector unit)."""
    return (k_matmul.matmul(a, b, block=8),)


def dsp_matmul_b32(a, b):
    """L1 ablation build: 32x32 tiles (3 x 4 KiB per tile set)."""
    return (k_matmul.matmul(a, b, block=32),)


# --------------------------------------------------------------------------
# pattern
# --------------------------------------------------------------------------

def naive_pattern(seq, pat):
    return (ref.pattern_ref(seq, pat),)


def dsp_pattern(seq, pat):
    return (k_pattern.pattern_count(seq, pat),)


# --------------------------------------------------------------------------
# fft
# --------------------------------------------------------------------------

def naive_fft(re, im):
    return (ref.fft_ref(re, im),)


def dsp_fft(re, im):
    return (k_fft.fft(re, im),)


VARIANTS = {
    "complement": {"naive": naive_complement, "dsp": dsp_complement},
    "conv2d": {"naive": naive_conv2d, "dsp": dsp_conv2d},
    "dotprod": {"naive": naive_dotprod, "dsp": dsp_dotprod},
    "matmul": {"naive": naive_matmul, "dsp": dsp_matmul},
    "pattern": {"naive": naive_pattern, "dsp": dsp_pattern},
    "fft": {"naive": naive_fft, "dsp": dsp_fft},
}
