//! Bench: regenerate Table 1 (paper §5.2) and measure the real substrate.
//!
//! Sim side: the calibrated DM3730 reproduces the paper's normal/VPE
//! columns and speedups.  Real side: wall-clock of the pure-Rust
//! reference loop (the "C program" on the host) vs the PJRT naive and
//! Pallas artifacts at artifact shapes.
//!
//! `cargo bench --bench table1`

use vpe::bench_harness::table1;
use vpe::util::bench::{bench, black_box, header};
use vpe::workloads::{self, WorkloadKind};

fn main() {
    // -- the paper table (simulated clock) -------------------------------
    let rows = table1::table1(20, false).expect("table1 harness");
    println!("{}", table1::render(&rows).to_markdown());

    // -- real substrate walls ---------------------------------------------
    header("Table 1 workloads — real execution at artifact shapes");

    // Pure-Rust baselines (the developer's naive loop, -O3).
    for kind in WorkloadKind::ALL {
        let inst = workloads::instance(kind, 42);
        bench(&format!("rust-naive/{}", kind.name()), 1, 5, || match kind {
            WorkloadKind::Complement => {
                let seq = inst.inputs[0].as_i32().unwrap();
                black_box(workloads::complement::reference(seq));
            }
            WorkloadKind::Conv2d => {
                let img = inst.inputs[0].as_i32().unwrap();
                let ker = inst.inputs[1].as_i32().unwrap();
                black_box(workloads::conv2d::reference(img, 128, 128, ker, 3));
            }
            WorkloadKind::Dotprod => {
                let x = inst.inputs[0].as_i32().unwrap();
                let y = inst.inputs[1].as_i32().unwrap();
                black_box(workloads::dotprod::reference(x, y));
            }
            WorkloadKind::Matmul => {
                let a = inst.inputs[0].as_i32().unwrap();
                let b = inst.inputs[1].as_i32().unwrap();
                black_box(workloads::matmul::reference(a, b, 128));
            }
            WorkloadKind::Pattern => {
                let s = inst.inputs[0].as_i32().unwrap();
                let p = inst.inputs[1].as_i32().unwrap();
                black_box(workloads::pattern::reference(s, p));
            }
            WorkloadKind::Fft => {
                let re = inst.inputs[0].as_f32().unwrap();
                let im = inst.inputs[1].as_f32().unwrap();
                black_box(workloads::fft::reference(re, im));
            }
        });
    }

    // PJRT artifacts (both builds), if present.
    #[cfg(not(feature = "pjrt"))]
    println!("(PJRT disabled — rebuild with --features pjrt for artifact walls)");
    #[cfg(feature = "pjrt")]
    match vpe::runtime::ArtifactStore::open_default() {
        Ok(store) => {
            for kind in WorkloadKind::ALL {
                let inst = workloads::instance(kind, 42);
                for name in [&inst.artifact_naive, &inst.artifact_dsp] {
                    match store.load(name) {
                        Ok(a) => {
                            let _ = a.execute(&inst.inputs).expect("warm");
                            bench(&format!("pjrt/{name}"), 1, 5, || {
                                black_box(a.execute(&inst.inputs).expect("execute"));
                            });
                        }
                        Err(e) => println!("{name}: unavailable ({e})"),
                    }
                }
            }
        }
        Err(e) => println!("(artifacts unavailable: {e} — run `make artifacts`)"),
    }
}
