//! Bench: policy ablation — the paper's blind offload vs the §2
//! alternatives, across all six workloads and a degraded-hardware
//! scenario.
//!
//! Reported metric: total simulated time for 40 iterations of each
//! workload (lower is better).  The static BAAR-like policy has no
//! warm-up but cannot revert; blind offload pays a warm-up and wins
//! whenever reality disagrees with predictions.
//!
//! `cargo bench --bench policies`

use vpe::coordinator::policies_ext::{
    EpsilonGreedyPolicy, HysteresisPolicy, PredictivePolicy,
};
use vpe::coordinator::policy::{
    AlwaysOffloadPolicy, BlindOffloadPolicy, NeverOffloadPolicy, OffloadPolicy,
};
use vpe::coordinator::{Vpe, VpeConfig};
use vpe::platform::dm3730;
use vpe::workloads::WorkloadKind;

fn policy(name: &str) -> Box<dyn OffloadPolicy> {
    match name {
        "never" => Box::new(NeverOffloadPolicy),
        "always" => Box::new(AlwaysOffloadPolicy),
        "blind" => Box::<BlindOffloadPolicy>::default(),
        "hysteresis" => Box::<HysteresisPolicy>::default(),
        "predictive" => Box::<PredictivePolicy>::default(),
        "eps-greedy" => Box::new(EpsilonGreedyPolicy::new(0.1, 0xE95)),
        _ => unreachable!(),
    }
}

fn total_sim_ms(kind: WorkloadKind, pol: &str, degrade: Option<f64>) -> f64 {
    let mut v = Vpe::with_policy(VpeConfig::sim_only(), policy(pol)).expect("vpe");
    if let Some(f) = degrade {
        v.soc_mut().degrade_target(dm3730::DSP, f);
    }
    let f = if kind == WorkloadKind::Matmul {
        v.register_matmul(500).expect("register")
    } else {
        v.register_workload(kind).expect("register")
    };
    let recs = v.run(f, 40).expect("run");
    recs.iter().map(|r| r.total_ns() as f64).sum::<f64>() / 1e6
}

const POLICIES: [&str; 6] = ["never", "always", "blind", "hysteresis", "predictive", "eps-greedy"];

fn print_scenario(title: &str, degrade: Option<f64>) {
    println!("\n== {title} (total sim ms for 40 iterations; lower is better) ==");
    print!("{:<14}", "workload");
    for p in POLICIES {
        print!(" {p:>12}");
    }
    println!();
    for kind in WorkloadKind::ALL {
        print!("{:<14}", kind.name());
        let base = total_sim_ms(kind, "never", degrade);
        for p in POLICIES {
            let ms = total_sim_ms(kind, p, degrade);
            print!(" {:>12}", format!("{:.0} ({:.1}x)", ms, base / ms));
        }
        println!();
    }
}

/// Trace-driven what-if: record one blind-offload matmul run, then
/// re-price it under every policy without re-simulating the platform —
/// the same comparison as the sim sweep above, but from a v3 trace.
fn print_whatif() {
    let mut v = Vpe::with_policy(VpeConfig::sim_only(), policy("blind")).expect("vpe");
    v.enable_tracing();
    let f = v.register_matmul(500).expect("register");
    v.run(f, 40).expect("run");
    let trace = v.trace().expect("tracing enabled").clone();
    println!(
        "\n== trace-driven what-if (matmul-500 x 40 recorded under blind: {:.0} ms) ==",
        trace.total_ms()
    );
    println!(
        "{:<14} {:>10} {:>9} {:>9} {:>9}",
        "policy", "total ms", "offloads", "reverts", "diverged"
    );
    for name in POLICIES {
        let mut p = policy(name);
        let o = vpe::coordinator::trace::replay(&trace, p.as_mut());
        println!(
            "{:<14} {:>10.0} {:>9} {:>9} {:>9}",
            name,
            o.total_ms,
            o.offloads,
            o.reverts,
            o.diverged()
        );
    }
    // Replaying the recording policy must reproduce the recorded run
    // bit-exactly — the decision-faithful replay guarantee.
    let mut blind = policy("blind");
    let o = vpe::coordinator::trace::replay(&trace, blind.as_mut());
    assert_eq!(o.diverged(), 0, "recording-policy replay must match:\n{}", o.divergence_report());
    assert_eq!(o.total_ns, trace.total_ns(), "recording-policy replay must re-price exactly");
}

fn main() {
    print_scenario("healthy DM3730", None);
    // A 40x-degraded DSP: static prediction keeps dispatching to it,
    // measurement-driven policies escape.
    print_scenario("thermally degraded DSP (40x)", Some(40.0));
    print_whatif();

    // Sanity assertions for the headline claims of the ablation.
    let blind_fft = total_sim_ms(WorkloadKind::Fft, "blind", None);
    let always_fft = total_sim_ms(WorkloadKind::Fft, "always", None);
    assert!(blind_fft < always_fft, "blind must recover on FFT");
    let blind_deg = total_sim_ms(WorkloadKind::Matmul, "blind", Some(40.0));
    let pred_deg = total_sim_ms(WorkloadKind::Matmul, "predictive", Some(40.0));
    assert!(blind_deg < pred_deg, "blind must escape a degraded DSP, static cannot");
    println!(
        "\nheadline checks passed: blind recovers on FFT, escapes a degraded DSP, and\n\
         trace replay under the recording policy reproduces the run exactly"
    );
}
