//! Bench: the profiler's cost — §3.1's "up to 20 % overhead" bound.
//!
//! Simulated side: end-to-end sim time of a workload loop with the
//! sampler off vs on (the overhead VPE charges itself).  Real side: the
//! wall cost of `PerfSampler::record` itself, which sits on the L3 hot
//! path and must stay in the tens of nanoseconds.
//!
//! `cargo bench --bench profiler_overhead`

use vpe::coordinator::{Vpe, VpeConfig};
use vpe::jit::module::FunctionId;
use vpe::platform::TargetId;
use vpe::profiler::counters::CounterSample;
use vpe::profiler::sampler::{PerfSampler, SamplerConfig};
use vpe::sim::SimRng;
use vpe::util::bench::{bench, black_box, header};
use vpe::workloads::WorkloadKind;

fn sim_total_ms(enabled: bool, overhead_frac: f64) -> f64 {
    let mut cfg = VpeConfig::sim_only();
    cfg.sampler.enabled = enabled;
    cfg.sampler.overhead_frac = overhead_frac;
    let mut v = Vpe::new(cfg).expect("vpe");
    // NeverOffload keeps the comparison apples-to-apples on the ARM.
    let mut v2 = Vpe::with_policy(
        {
            let mut c = VpeConfig::sim_only();
            c.sampler.enabled = enabled;
            c.sampler.overhead_frac = overhead_frac;
            c
        },
        Box::new(vpe::coordinator::policy::NeverOffloadPolicy),
    )
    .expect("vpe");
    std::mem::swap(&mut v, &mut v2);
    let f = v.register_workload(WorkloadKind::Conv2d).expect("register");
    let recs = v.run(f, 40).expect("run");
    recs.iter().map(|r| r.total_ns() as f64).sum::<f64>() / 1e6
}

fn main() {
    println!("simulated profiling overhead (conv2d x40, ARM-pinned):");
    let off = sim_total_ms(false, 0.05);
    for frac in [0.02, 0.05, 0.10, 0.20] {
        let on = sim_total_ms(true, frac);
        println!(
            "  overhead_frac {frac:>5.2}: {off:>9.1} ms -> {on:>9.1} ms  (+{:.1}%)",
            (on / off - 1.0) * 100.0
        );
    }
    let worst = sim_total_ms(true, 0.20) / off - 1.0;
    assert!(worst < 0.35, "overhead {worst} blew past the paper envelope + bursts");

    header("sampler hot-path (real wall clock)");
    let mut sampler = PerfSampler::new(SamplerConfig::default()).expect("sampler");
    let mut rng = SimRng::seeded(1);
    let sample = CounterSample {
        cycles: 1_000_000,
        instructions: 500_000,
        cache_misses: 1000,
        branch_misses: 100,
        page_faults: 0,
    };
    bench("PerfSampler::record", 1000, 200_000, || {
        black_box(sampler.record(FunctionId(0), TargetId::HOST, sample, 1_000_000, &mut rng));
    });
    bench("CounterSample::synthesize", 1000, 200_000, || {
        black_box(CounterSample::synthesize(
            WorkloadKind::Matmul,
            1e6,
            1e6,
            TargetId::HOST,
            1_000_000_000,
        ));
    });
}
