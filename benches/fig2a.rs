//! Bench: regenerate Fig 2(a) — per-algorithm execution times, ARM vs
//! DSP-under-VPE, on a log scale (rendered as ASCII bars).
//!
//! `cargo bench --bench fig2a`

use vpe::bench_harness::{fig2, table1};

fn main() {
    let t = fig2::fig2a(20).expect("fig2a harness");
    println!("{}", t.to_markdown());

    // ASCII log-scale bars (1 char per 0.1 decade above 10 ms).
    println!("log-scale view (each # = 0.1 decade):");
    let rows = table1::table1(20, false).expect("table1");
    for r in &rows {
        let bar = |ms: f64| "#".repeat(((ms.log10() - 1.0).max(0.0) * 10.0) as usize);
        println!("{:<14} ARM {:>9.1} ms |{}", r.kind.name(), r.normal_ms, bar(r.normal_ms));
        println!("{:<14} DSP {:>9.1} ms |{}", "", r.vpe_ms, bar(r.vpe_ms));
    }
}
