//! Bench: batched-dispatch amortization, batch width 1 -> 16 (sim clock).
//!
//! Streams waves of queued 128x128 matmuls at a message-passing remote
//! unit and sweeps the batch width cap.  Per-call cost should fall as
//! ~`setup/width + wire/serde + compute`: the fixed ~100 ms transport
//! setup amortizes across each coalesced batch while per-call costs
//! stay put.  Times are simulated (the cost model drives the clock), so
//! the sweep isolates the *scheduling* win from backend numerics.
//!
//! `cargo bench --bench batching`

use vpe::coordinator::policy::AlwaysOffloadPolicy;
use vpe::coordinator::{Vpe, VpeConfig};
use vpe::platform::{MpiModel, Soc};

/// Steady-state per-call cost (ms) and total saved setup at one width.
fn per_call_ms(width: usize, waves: usize) -> vpe::Result<(f64, u64)> {
    let mut cfg = VpeConfig::sim_only();
    cfg.exec_noise_frac = 0.0;
    cfg.max_queue_per_target = width.max(1);
    cfg.max_batch_width = width.max(1);
    cfg.sampler.analysis_period = u64::MAX; // no bursts: isolate transport
    let mut v = Vpe::with_policy(cfg, Box::new(AlwaysOffloadPolicy))?;
    *v.soc_mut() = Soc::dm3730_message_passing(MpiModel::cluster_10gbe());
    let f = v.register_matmul(128)?;
    v.call(f)?; // warm-up commits the offload
    let t0 = v.clock().now_ns();
    let mut calls = 0usize;
    for _ in 0..waves {
        for _ in 0..width {
            v.submit(f)?;
            calls += 1;
        }
        v.drain()?;
    }
    let elapsed_ns = v.clock().now_ns() - t0;
    assert_eq!(v.in_flight(), 0);
    Ok((elapsed_ns as f64 / 1e6 / calls as f64, v.saved_setup_ns()))
}

fn main() -> vpe::Result<()> {
    println!("== batched dispatch: per-call cost vs batch width (128x128 matmul, MPI link) ==");
    println!(
        "{:>6} {:>14} {:>12} {:>14}",
        "width", "per-call ms", "calls/s", "saved ms"
    );
    let mut prev = f64::INFINITY;
    for width in [1usize, 2, 4, 8, 16] {
        let (ms, saved_ns) = per_call_ms(width, 6)?;
        println!(
            "{:>6} {:>14.2} {:>12.1} {:>14.0}",
            width,
            ms,
            1000.0 / ms,
            saved_ns as f64 / 1e6
        );
        assert!(
            ms <= prev * 1.001,
            "wider batches must never cost more per call ({ms:.2} ms after {prev:.2} ms)"
        );
        prev = ms;
    }
    println!("\n(per-call cost ~ setup/width + wire/serde + compute: the Fig-2b setup amortizes)");
    Ok(())
}
