//! Bench: regenerate Fig 2(b) — matmul execution time vs matrix size,
//! the ~100 ms DSP setup plateau, the crossover, and the decision-tree
//! learner.  Also measures the *real* PJRT artifacts across the AOT'd
//! sizes plus the pure-Rust naive/blocked baselines.
//!
//! `cargo bench --bench fig2b`

use vpe::bench_harness::fig2;
use vpe::util::bench::{bench, black_box, header};
use vpe::workloads::{matmul, shapes};

fn main() {
    // -- simulated sweep (the figure itself) ------------------------------
    let (points, tree) = fig2::fig2b(&fig2::default_sizes(), 5, 0xF162B);
    println!("{}", fig2::render_fig2b(&points, &tree).to_markdown());
    println!(
        "analytic crossover N = {:.0}; learned N = {} (paper: ~75)\n",
        fig2::analytic_crossover(),
        tree.root_threshold().map(|t| format!("{t:.0}")).unwrap_or("-".into())
    );

    // -- real execution across sizes --------------------------------------
    header("matmul — real execution across sizes");
    #[cfg(feature = "pjrt")]
    let store = vpe::runtime::ArtifactStore::open_default().ok();
    for n in shapes::MATMUL_SIZES {
        let inst = matmul::instance(n, 42);
        let a = inst.inputs[0].as_i32().unwrap().to_vec();
        let b = inst.inputs[1].as_i32().unwrap().to_vec();
        bench(&format!("rust-naive/matmul{n}"), 1, 5, || {
            black_box(matmul::reference(&a, &b, n));
        });
        bench(&format!("rust-blocked/matmul{n}"), 1, 5, || {
            black_box(matmul::reference_blocked(&a, &b, n, 32));
        });
        #[cfg(feature = "pjrt")]
        if let Some(store) = &store {
            for name in [&inst.artifact_naive, &inst.artifact_dsp] {
                if let Ok(art) = store.load(name) {
                    let _ = art.execute(&inst.inputs).expect("warm");
                    bench(&format!("pjrt/{name}"), 1, 5, || {
                        black_box(art.execute(&inst.inputs).expect("execute"));
                    });
                }
            }
        }
    }
}
