//! Bench: L1 Pallas tile-size ablation (EXPERIMENTS.md §Perf).
//!
//! The same 128x128 int32 matmul AOT'd with three Pallas block shapes
//! (8, 16, 32), executed through the PJRT CPU substrate.  On real TPU
//! hardware the tile size trades VMEM footprint against MXU utilization;
//! on the interpret-mode CPU substrate it trades loop-nest overhead
//! (grid steps) against working-set locality — the *structural* knob is
//! identical, which is what this ablation exercises.
//!
//! `cargo bench --bench kernel_blocks`

#[cfg(feature = "pjrt")]
use vpe::util::bench::{bench, black_box, header};
#[cfg(feature = "pjrt")]
use vpe::workloads::matmul;

#[cfg(not(feature = "pjrt"))]
fn main() {
    println!("kernel_blocks measures PJRT artifacts; rebuild with --features pjrt");
}

#[cfg(feature = "pjrt")]
fn main() {
    let store = match vpe::runtime::ArtifactStore::open_default() {
        Ok(s) => s,
        Err(e) => {
            println!("artifacts unavailable ({e}) — run `make artifacts`");
            return;
        }
    };
    let inst = matmul::instance(128, 42);

    header("matmul 128x128 int32 — Pallas tile-size ablation (PJRT CPU)");
    let mut results = Vec::new();
    for name in ["matmul128__naive", "matmul128__dsp_b8", "matmul128__dsp", "matmul128__dsp_b32"]
    {
        match store.load(name) {
            Ok(a) => {
                let (out, _) = a.execute(&inst.inputs).expect("warm");
                assert!(
                    inst.expected.allclose(&out, 0.0),
                    "{name}: wrong output — ablation build is broken"
                );
                let r = bench(&format!("pjrt/{name}"), 2, 8, || {
                    black_box(a.execute(&inst.inputs).expect("execute"));
                });
                results.push((name, r.mean_ns));
            }
            Err(e) => println!("{name}: unavailable ({e})"),
        }
    }

    if results.len() == 4 {
        let best = results[1..]
            .iter()
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .expect("non-empty");
        println!(
            "\nbest DSP-build tile: {} ({:.2} ms) — recorded in EXPERIMENTS.md §Perf",
            best.0,
            best.1 / 1e6
        );
    }
}
