//! Bench: transport ablation — shared memory (the paper's setting) vs a
//! message-passing layer (the §3.3 alternative, as in BAAR [17]).
//!
//! The question: how much of Table 1 survives when the remote target no
//! longer shares memory and every dispatch ships its payload?  Answer:
//! the memory-bound wins evaporate (complement, dotprod, pattern ship
//! tens-to-hundreds of MiB per call), the compute-dense matmul survives
//! on a fast link, and the crossover barely moves (setup-dominated) —
//! quantifying why the paper restricts VPE to shared-memory systems.
//!
//! `cargo bench --bench transport`

use vpe::platform::{dm3730, MpiModel, Soc};
use vpe::workloads::{matmul_scale, paper_scale, WorkloadKind};

fn row(soc: &Soc, kind: WorkloadKind) -> (f64, f64) {
    let scale =
        if kind == WorkloadKind::Matmul { matmul_scale(500) } else { paper_scale(kind) };
    let arm =
        soc.call_scaled_ns(kind, &scale, dm3730::ARM).expect("arm healthy") as f64 / 1e6;
    let dsp =
        soc.call_scaled_ns(kind, &scale, dm3730::DSP).expect("dsp healthy") as f64 / 1e6;
    (arm, dsp)
}

fn crossover(soc: &Soc) -> Option<u64> {
    (8..=2048).find(|&n| {
        let s = matmul_scale(n);
        let arm = soc.call_scaled_ns(WorkloadKind::Matmul, &s, dm3730::ARM).unwrap();
        let dsp = soc.call_scaled_ns(WorkloadKind::Matmul, &s, dm3730::DSP).unwrap();
        dsp < arm
    })
}

fn main() {
    let shared = Soc::dm3730();
    let mpi_slow = Soc::dm3730_message_passing(MpiModel::embedded_ethernet());
    let mpi_fast = Soc::dm3730_message_passing(MpiModel::cluster_10gbe());

    println!("== Table 1 under three transports (DSP speedup vs ARM; sim) ==");
    println!(
        "{:<14} {:>10} {:>16} {:>18} {:>16}",
        "workload", "payload", "shared-memory", "MPI embedded", "MPI 10GbE"
    );
    for kind in WorkloadKind::ALL {
        let scale =
            if kind == WorkloadKind::Matmul { matmul_scale(500) } else { paper_scale(kind) };
        let fmt = |soc: &Soc| {
            let (arm, dsp) = row(soc, kind);
            format!("{:.1}x", arm / dsp)
        };
        println!(
            "{:<14} {:>8.1}MB {:>16} {:>18} {:>16}",
            kind.name(),
            scale.payload_bytes as f64 / 1e6,
            fmt(&shared),
            fmt(&mpi_slow),
            fmt(&mpi_fast),
        );
    }

    println!("\n== Fig 2b matmul crossover under each transport ==");
    for (name, soc) in
        [("shared-memory", &shared), ("MPI embedded", &mpi_slow), ("MPI 10GbE", &mpi_fast)]
    {
        match crossover(soc) {
            Some(n) => println!("  {name:<14} DSP wins from N = {n}"),
            None => println!("  {name:<14} DSP never wins up to N = 2048"),
        }
    }

    // Headline assertions.
    let (arm, dsp) = row(&shared, WorkloadKind::Complement);
    assert!(dsp < arm, "shared memory: complement must win on the DSP");
    let (arm, dsp) = row(&mpi_slow, WorkloadKind::Complement);
    assert!(
        dsp > arm,
        "embedded MPI: the 64 MiB complement payload must kill the win"
    );
    let (arm, dsp) = row(&mpi_fast, WorkloadKind::Matmul);
    assert!(dsp < arm, "10GbE MPI: the compute-dense matmul must survive");
    let c_shared = crossover(&shared).expect("shared crossover");
    let c_mpi = crossover(&mpi_fast).expect("10GbE crossover");
    assert!(c_mpi > c_shared, "message passing must push the crossover right");
    println!("\nheadline checks passed: shared memory is load-bearing for Table 1");
}
