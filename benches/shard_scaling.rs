//! Bench: sharded fan-out scaling, 1 -> N units (sim clock).
//!
//! Sweeps the number of registered accelerator units and reports, for a
//! 500x500 matmul, the planner's fan-out width, the sharded makespan,
//! and the speedup over the best single-unit dispatch of the same call.
//! Times are simulated (the cost model drives the clock), so the sweep
//! isolates the *scheduling* win from backend numerics.
//!
//! `cargo bench --bench shard_scaling`

use vpe::coordinator::{Vpe, VpeConfig};
use vpe::platform::{TargetSpec, TransferModel, Transport};
use vpe::workloads::{matmul_scale, WorkloadKind};

/// A platform with `extra` accelerator units besides the DM3730 pair.
fn vpe_with_units(extra: usize) -> vpe::Result<Vpe> {
    let mut cfg = VpeConfig::sim_only();
    cfg.exec_noise_frac = 0.0;
    let mut v = Vpe::new(cfg)?;
    for i in 0..extra {
        let id = v.soc_mut().add_target(
            TargetSpec::new(&format!("accel-{i}"), 1_000_000_000).with_transport(
                Transport::SharedMemory(TransferModel {
                    dispatch_fixed_ns: 10_000_000 + 5_000_000 * i as u64,
                    per_param_byte_ns: 1.0,
                }),
            ),
        );
        // Progressively slower extra units: 0.2, 0.3, 0.4, ... ns/MAC.
        v.soc_mut()
            .cost
            .set_rate(WorkloadKind::Matmul, id, 0.2 + 0.1 * i as f64);
    }
    Ok(v)
}

fn main() -> vpe::Result<()> {
    println!("== sharded fan-out scaling (500x500 matmul, sim clock) ==");
    println!(
        "{:>6} {:>8} {:>14} {:>16} {:>9}",
        "units", "shards", "makespan ms", "best single ms", "speedup"
    );
    let scale = matmul_scale(500);
    for extra in 0..=4 {
        let mut v = vpe_with_units(extra)?;
        let f = v.register_matmul(500)?;
        let best_single = v
            .soc()
            .targets()
            .filter_map(|(id, _)| v.soc().call_scaled_ns(WorkloadKind::Matmul, &scale, id).ok())
            .min()
            .unwrap_or(u64::MAX);
        let rec = v.call_sharded(f)?;
        // Sanity: the queue drained and nothing leaked.
        assert_eq!(v.in_flight(), 0);
        assert_eq!(v.soc().shared.used_bytes(), 0);
        println!(
            "{:>6} {:>8} {:>14.1} {:>16.1} {:>8.2}x",
            2 + extra,
            rec.shards,
            rec.exec_ns as f64 / 1e6,
            best_single as f64 / 1e6,
            best_single as f64 / rec.exec_ns as f64,
        );
    }
    println!("\n(speedup < 1x never happens: the planner falls back to one shard when fanning out would lose)");
    Ok(())
}
