//! Bench: regenerate Fig 3 — the video-prototype frame-rate / CPU-load
//! story, plus an ablation over the profiler's analysis period (the
//! knob behind the Fig 3c CPU spikes).
//!
//! `cargo bench --bench fig3`

use vpe::bench_harness::fig3;

fn main() {
    let s = fig3::fig3(300, 60, false).expect("fig3 harness");
    println!("{}", fig3::render(&s).to_markdown());
    println!(
        "offload at frame {:?}, {} analysis bursts over {} frames\n",
        s.offload_frame,
        s.bursts,
        s.frames.len()
    );

    // Compact per-phase time series (what the paper plots in 3c).
    println!("frame     fps   cpu%  target");
    for f in s.frames.iter().step_by(15) {
        println!(
            "{:>5} {:>7.2} {:>6.0}  {}",
            f.frame,
            f.fps,
            f.cpu_load * 100.0,
            if f.conv_target.is_host() { "ARM" } else { "DSP" }
        );
    }

    // Ablation: burst period vs steady-state fps after offload.
    println!("\nablation — analysis period vs post-offload fps / CPU spikes:");
    println!("{:>8} {:>10} {:>10} {:>8}", "period", "fps", "cpu%", "bursts");
    for period in [2u64, 4, 8, 16, 32] {
        let s = fig3::fig3_with_period(300, 60, period).expect("fig3 ablation");
        println!(
            "{:>8} {:>10.2} {:>10.0} {:>8}",
            period,
            s.fps_after,
            s.cpu_after * 100.0,
            s.bursts
        );
    }
}
