//! Bench: the L3 coordinator hot path, piece by piece — the perf-pass
//! target list (EXPERIMENTS.md §Perf).
//!
//! The paper's wrapper adds "a call overhead [that] quickly becomes
//! negligible"; for that to hold here, the dispatch decision must stay
//! in the nanosecond range and the full sim-only `Vpe::call` (everything
//! VPE does around the actual compute) in the low microseconds.
//!
//! `cargo bench --bench hotpath`

use vpe::coordinator::{Vpe, VpeConfig};
use vpe::jit::module::{FunctionId, IrFunction, IrModule};
use vpe::jit::wrapper::DispatchTable;
use vpe::platform::memory::SharedRegion;
use vpe::platform::{dm3730, Soc};
use vpe::util::bench::{bench, black_box, header};
use vpe::workloads::WorkloadKind;

fn main() {
    header("L3 coordinator hot path");

    // Wrapper dispatch (the Fig 1 pointer load).
    let mut m = IrModule::new("bench");
    for i in 0..64 {
        m.add_function(IrFunction::user(&format!("f{i}"), Some(WorkloadKind::Matmul)));
    }
    m.finalize();
    let table = DispatchTable::for_module(&m).expect("table");
    bench("DispatchTable::dispatch", 10_000, 1_000_000, || {
        black_box(table.dispatch(FunctionId(17)).expect("dispatch"));
    });
    bench("DispatchTable::set_target+reset", 10_000, 500_000, || {
        table.set_target(FunctionId(17), dm3730::DSP).expect("set");
        table.reset(FunctionId(17)).expect("reset");
    });

    // Shared-region parameter staging.
    let mut region = SharedRegion::dm3730();
    bench("SharedRegion alloc+free", 10_000, 500_000, || {
        let a = region.alloc(64).expect("alloc");
        region.free(a).expect("free");
    });

    // Cost-model evaluation.
    let soc = Soc::dm3730();
    bench("Soc::call_ns", 10_000, 1_000_000, || {
        black_box(
            soc.call_ns(WorkloadKind::Matmul, 2_097_152.0, 48, dm3730::DSP)
                .expect("call_ns"),
        );
    });

    // Full sim-only coordinator call (steady state on the DSP).
    let mut v = Vpe::new(VpeConfig::sim_only()).expect("vpe");
    let f = v.register_workload(WorkloadKind::Matmul).expect("register");
    v.run(f, 15).expect("warmup");
    assert_eq!(v.current_target(f).expect("target"), dm3730::DSP);
    bench("Vpe::call (sim-only, steady)", 1000, 100_000, || {
        black_box(v.call(f).expect("call"));
    });

    // Event-log render (reporting path, not hot, but bounded).
    bench("EventLog::to_text", 100, 10_000, || {
        black_box(v.events().to_text());
    });
}
